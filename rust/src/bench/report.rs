//! The BENCH artifact pipeline behind `flare bench-report`.
//!
//! Four operations over the perf artifacts CI passes around:
//!
//! * [`fold`] — merge the `results/*.json` dumps written by the benches
//!   into one `BENCH_native.json` (per-op median ns + the measurement
//!   extras, worker threads, git sha), self-validated after writing.
//! * [`check`] — schema validation of a folded artifact, replacing the
//!   shell `jq` probes bench-smoke used to run: top-level fields, every
//!   op well-formed, `serve_open_loop_*` ops carrying the open-loop
//!   contract (`goodput_req_s`, `load_factor`, `p99_ms`), and `fig5_*`
//!   ops carrying the memory contract ([`GATED_MEMORY_KEYS`]).
//! * [`compare`] — the regression gate: fail when any shared
//!   `(bench, name)` median regresses past the bound vs a baseline —
//!   and likewise for the gated memory columns, which also fail when a
//!   baseline op carries them but the fresh run dropped them.
//! * [`calibrate`] — rewrite `BENCH_baseline.json` from a fresh
//!   `BENCH_native.json`, preserving the baseline schema (including the
//!   gated memory columns) and stamping a provenance note (which sha it
//!   was calibrated from).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::{parse, Json};

/// Measurement extras that gate like time: memory regressions on these
/// keys fail [`compare`] exactly as median regressions do, [`check`]
/// requires them on `fig5_*` ops, and [`calibrate`] preserves them (and
/// only them) in the committed baseline.
pub const GATED_MEMORY_KEYS: [&str; 2] = ["peak_rss_gb", "bytes_per_token"];

/// One folded measurement row, carrying what the regression gate reads.
pub struct MeasuredOp {
    pub bench: String,
    pub name: String,
    pub median_ns: f64,
    /// gated memory columns present on this op (subset of
    /// [`GATED_MEMORY_KEYS`])
    pub memory: Vec<(String, f64)>,
}

/// What [`fold`] produced: enough for `--compare` without re-parsing.
pub struct FoldOutcome {
    pub path: PathBuf,
    pub ops: usize,
    /// per-op rows for the perf + memory gate
    pub measured: Vec<MeasuredOp>,
}

/// Merge bench dump files from `dirs` into the `BENCH_native.json` schema
/// at `out_path`.  Non-array JSON files are skipped (results/ also holds
/// e2e records); measurement `extras` are carried into the op entries so
/// [`check`] can validate bench-specific contracts downstream.
pub fn fold(
    dirs: &[PathBuf],
    out_path: &Path,
    threads: usize,
    sha: &str,
) -> anyhow::Result<FoldOutcome> {
    let mut files: Vec<PathBuf> = Vec::new();
    for dir in dirs {
        if let Ok(rd) = std::fs::read_dir(dir) {
            files.extend(
                rd.filter_map(|e| e.ok().map(|e| e.path()))
                    .filter(|p| p.extension().map(|x| x == "json").unwrap_or(false)),
            );
        }
    }
    files.sort();
    anyhow::ensure!(!files.is_empty(), "no *.json bench dumps in {dirs:?}");
    let mut ops: Vec<Json> = Vec::new();
    let mut measured: Vec<MeasuredOp> = Vec::new();
    for path in &files {
        let text = std::fs::read_to_string(path)?;
        let parsed =
            parse(&text).map_err(|e| anyhow::anyhow!("malformed bench dump {path:?}: {e}"))?;
        let Some(arr) = parsed.as_arr() else {
            eprintln!("skipping {path:?}: not a bench measurement array");
            continue;
        };
        let bench = path
            .file_stem()
            .and_then(|s| s.to_str())
            .unwrap_or("bench")
            .to_string();
        for m in arr {
            let name = m
                .get("name")
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("measurement without name in {path:?}"))?;
            let p50 = m.get("p50_ms").as_f64().ok_or_else(|| {
                anyhow::anyhow!("measurement {name:?} without p50_ms in {path:?}")
            })?;
            anyhow::ensure!(
                p50.is_finite() && p50 >= 0.0,
                "measurement {name:?} has invalid p50_ms {p50}"
            );
            let iters = m.get("iters").as_f64().unwrap_or(0.0);
            let mut memory: Vec<(String, f64)> = Vec::new();
            for key in GATED_MEMORY_KEYS {
                if let Some(x) = m.get("extras").get(key).as_f64() {
                    memory.push((key.to_string(), x));
                }
            }
            measured.push(MeasuredOp {
                bench: bench.clone(),
                name: name.to_string(),
                median_ns: p50 * 1e6,
                memory,
            });
            let mut fields = vec![
                ("bench", Json::str(&bench)),
                ("name", Json::str(name)),
                ("median_ns", Json::num(p50 * 1e6)),
                ("iters", Json::num(iters)),
            ];
            // carry measurement extras through the fold — bench-specific
            // contracts (the open-loop goodput fields) live there
            if let Some(extras) = m.get("extras").as_obj() {
                if !extras.is_empty() {
                    fields.push(("extras", Json::Obj(extras.clone())));
                }
            }
            ops.push(Json::obj(fields));
        }
    }
    anyhow::ensure!(!ops.is_empty(), "bench dumps contained no measurements");
    let count = ops.len();
    let report = Json::obj(vec![
        ("schema", Json::num(1.0)),
        ("backend", Json::str("native")),
        ("git_sha", Json::str(sha)),
        ("threads", Json::num(threads as f64)),
        ("ops", Json::Arr(ops)),
    ]);
    crate::util::fsio::atomic_write(out_path, report.to_string().as_bytes())?;
    // self-check: the artifact must round-trip through the validator
    let n = check(out_path)?;
    anyhow::ensure!(n == count, "written {out_path:?} failed validation");
    Ok(FoldOutcome {
        path: out_path.to_path_buf(),
        ops: count,
        measured,
    })
}

/// Validate a folded BENCH artifact; returns the op count.  This is the
/// one schema contract bench-smoke enforces (formerly four `jq` lines).
pub fn check(path: &Path) -> anyhow::Result<usize> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {path:?}: {e}"))?;
    let v = parse(&text).map_err(|e| anyhow::anyhow!("malformed {path:?}: {e}"))?;
    anyhow::ensure!(
        v.get("schema").as_usize() == Some(1),
        "{path:?}: schema must be 1"
    );
    anyhow::ensure!(
        !v.req_str("backend")?.is_empty(),
        "{path:?}: backend must be a non-empty string"
    );
    anyhow::ensure!(
        !v.req_str("git_sha")?.is_empty(),
        "{path:?}: git_sha must be a non-empty string"
    );
    anyhow::ensure!(
        v.req_usize("threads")? >= 1,
        "{path:?}: threads must be >= 1"
    );
    let ops = v
        .get("ops")
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("{path:?}: missing ops array"))?;
    anyhow::ensure!(!ops.is_empty(), "{path:?}: ops must be non-empty");
    for op in ops {
        let name = op.req_str("name")?;
        op.req_str("bench")?;
        let med = op.req_f64("median_ns")?;
        anyhow::ensure!(
            med.is_finite() && med >= 0.0,
            "{path:?}: op {name:?} has invalid median_ns {med}"
        );
        anyhow::ensure!(
            op.req_f64("iters")? >= 0.0,
            "{path:?}: op {name:?} has invalid iters"
        );
        // the open-loop serving ops must report the overload contract
        if name.starts_with("serve_open_loop") {
            let extras = op.get("extras");
            for key in ["goodput_req_s", "load_factor", "p99_ms"] {
                let x = extras.get(key).as_f64().ok_or_else(|| {
                    anyhow::anyhow!("{path:?}: open-loop op {name:?} lacks extras.{key}")
                })?;
                anyhow::ensure!(
                    x.is_finite() && x >= 0.0,
                    "{path:?}: open-loop op {name:?} has invalid {key} = {x}"
                );
            }
            anyhow::ensure!(
                extras.get("load_factor").as_f64().unwrap_or(0.0) > 0.0,
                "{path:?}: open-loop op {name:?} must have load_factor > 0"
            );
        }
        // the fig5 scaling ops must report the memory contract
        if name.starts_with("fig5_") {
            let extras = op.get("extras");
            for key in GATED_MEMORY_KEYS {
                let x = extras.get(key).as_f64().ok_or_else(|| {
                    anyhow::anyhow!("{path:?}: fig5 op {name:?} lacks extras.{key}")
                })?;
                anyhow::ensure!(
                    x.is_finite() && x > 0.0,
                    "{path:?}: fig5 op {name:?} has invalid {key} = {x}"
                );
            }
        }
    }
    Ok(ops.len())
}

/// Regression gate: every `(bench, name)` shared between `measured` and
/// the baseline must stay within `max_reg`x of the baseline median — and
/// within `max_reg`x on every gated memory column the baseline records.
/// A baseline memory column the fresh run no longer reports fails too:
/// silently dropping `peak_rss_gb` must not read as a pass.
pub fn compare(measured: &[MeasuredOp], base_path: &Path, max_reg: f64) -> anyhow::Result<()> {
    anyhow::ensure!(max_reg > 0.0, "--max-regression must be positive");
    let base = parse(&std::fs::read_to_string(base_path)?)
        .map_err(|e| anyhow::anyhow!("malformed baseline {base_path:?}: {e}"))?;
    let mut baseline: BTreeMap<(String, String), (f64, Vec<(String, f64)>)> = Default::default();
    if let Some(arr) = base.get("ops").as_arr() {
        for op in arr {
            if let (Some(b), Some(nm), Some(med)) = (
                op.get("bench").as_str(),
                op.get("name").as_str(),
                op.get("median_ns").as_f64(),
            ) {
                let mut mem: Vec<(String, f64)> = Vec::new();
                for key in GATED_MEMORY_KEYS {
                    if let Some(x) = op.get("extras").get(key).as_f64() {
                        mem.push((key.to_string(), x));
                    }
                }
                baseline.insert((b.to_string(), nm.to_string()), (med, mem));
            }
        }
    }
    let mut compared = 0usize;
    let mut regressions: Vec<String> = Vec::new();
    for op in measured {
        let Some((base_ns, base_mem)) = baseline.get(&(op.bench.clone(), op.name.clone()))
        else {
            continue;
        };
        let (bench, op_name, median_ns) = (&op.bench, &op.name, op.median_ns);
        if *base_ns <= 0.0 {
            continue;
        }
        compared += 1;
        let ratio = median_ns / base_ns;
        if ratio > max_reg {
            regressions.push(format!(
                "{bench}/{op_name}: {median_ns:.0} ns vs baseline {base_ns:.0} ns \
                 ({ratio:.2}x > {max_reg:.2}x)"
            ));
        }
        for (key, base_x) in base_mem {
            if *base_x <= 0.0 {
                continue;
            }
            let Some((_, x)) = op.memory.iter().find(|(k, _)| k == key) else {
                regressions.push(format!(
                    "{bench}/{op_name}: baseline records memory column {key} \
                     but this run did not report it"
                ));
                continue;
            };
            let r = x / base_x;
            if r > max_reg {
                regressions.push(format!(
                    "{bench}/{op_name}: {key} {x:.4} vs baseline {base_x:.4} \
                     ({r:.2}x > {max_reg:.2}x)"
                ));
            }
        }
    }
    anyhow::ensure!(
        compared > 0,
        "perf gate compared 0 ops against {base_path:?} — baseline and run share no \
         benchmark names; recalibrate with `flare bench-report --calibrate` (see README)"
    );
    if regressions.is_empty() {
        println!("perf gate: {compared} shared ops within {max_reg:.2}x of {base_path:?}");
        Ok(())
    } else {
        for r in &regressions {
            eprintln!("REGRESSION {r}");
        }
        anyhow::bail!(
            "{} of {compared} benchmark(s) regressed more than {max_reg}x vs {base_path:?}.\n\
             If this change is a deliberate perf trade (or the baseline is stale), refresh \
             the baseline from a green bench-smoke run on comparable hardware:\n\
             \x20 cargo run -p flare --release -- bench-report --calibrate BENCH_native.json \
             --out BENCH_baseline.json\n\
             — and commit the result (see README \"Performance\", or the workflow_dispatch \
             `calibrate-baseline` CI job which uploads a refreshed baseline artifact).",
            regressions.len()
        );
    }
}

/// Rewrite the committed perf baseline from a fresh, validated
/// `BENCH_native.json`: same schema (so [`compare`] keeps working), plus a
/// provenance note recording which run it was calibrated from.  Returns
/// the op count.
pub fn calibrate(native_path: &Path, baseline_path: &Path) -> anyhow::Result<usize> {
    let count = check(native_path)?;
    let v = parse(&std::fs::read_to_string(native_path)?)?;
    let sha = v.req_str("git_sha")?.to_string();
    let threads = v.req_usize("threads")?;
    // strip per-run extras down to what compare() reads — median plus the
    // gated memory columns — so recalibration diffs stay reviewable
    let mut ops: Vec<Json> = Vec::new();
    for op in v.get("ops").as_arr().unwrap_or(&[]) {
        let mut fields = vec![
            ("bench", Json::str(op.req_str("bench")?)),
            ("name", Json::str(op.req_str("name")?)),
            ("median_ns", Json::num(op.req_f64("median_ns")?)),
            ("iters", Json::num(op.req_f64("iters")?)),
        ];
        let mut mem: BTreeMap<String, Json> = Default::default();
        for key in GATED_MEMORY_KEYS {
            if let Some(x) = op.get("extras").get(key).as_f64() {
                mem.insert(key.to_string(), Json::num(x));
            }
        }
        if !mem.is_empty() {
            fields.push(("extras", Json::Obj(mem)));
        }
        ops.push(Json::obj(fields));
    }
    let note = format!(
        "Calibrated from BENCH_native.json at {sha} ({threads} threads). Regenerate with \
         `flare bench-report --calibrate BENCH_native.json --out BENCH_baseline.json` or the \
         workflow_dispatch calibrate-baseline CI job."
    );
    let report = Json::obj(vec![
        ("schema", Json::num(1.0)),
        ("backend", Json::str(v.req_str("backend")?)),
        ("git_sha", Json::str(&sha)),
        ("threads", Json::num(threads as f64)),
        ("note", Json::str(&note)),
        ("ops", Json::Arr(ops)),
    ]);
    crate::util::fsio::atomic_write(baseline_path, report.to_string().as_bytes())?;
    // the freshly written baseline must itself validate
    check(baseline_path)?;
    Ok(count)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("flare_report_{tag}"));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn write_dump(dir: &Path, bench: &str, body: &str) {
        std::fs::write(dir.join(format!("{bench}.json")), body).unwrap();
    }

    fn mop(bench: &str, name: &str, median_ns: f64) -> MeasuredOp {
        MeasuredOp {
            bench: bench.to_string(),
            name: name.to_string(),
            median_ns,
            memory: Vec::new(),
        }
    }

    fn mop_mem(bench: &str, name: &str, median_ns: f64, mem: &[(&str, f64)]) -> MeasuredOp {
        MeasuredOp {
            memory: mem.iter().map(|(k, v)| (k.to_string(), *v)).collect(),
            ..mop(bench, name, median_ns)
        }
    }

    #[test]
    fn fold_preserves_extras_and_validates() {
        let dir = tmp("fold");
        write_dump(
            &dir,
            "serve_open_loop",
            r#"[{"name": "serve_open_loop_x1", "iters": 10, "total_s": 1.0,
                 "p50_ms": 2.0, "p95_ms": 3.0,
                 "extras": {"goodput_req_s": 9.5, "load_factor": 1.0, "p99_ms": 4.0}}]"#,
        );
        write_dump(
            &dir,
            "fig2_scaling",
            r#"[{"name": "flare_n1024_m64", "iters": 5, "p50_ms": 1.5, "extras": {}}]"#,
        );
        // a non-array dump must be skipped, not fatal
        write_dump(&dir, "e2e_record", r#"{"kind": "e2e", "loss": 0.1}"#);
        let out = dir.join("BENCH_native.json");
        let f = fold(&[dir.clone()], &out, 4, "abc123").unwrap();
        assert_eq!(f.ops, 2);
        assert_eq!(f.measured.len(), 2);
        let v = parse(&std::fs::read_to_string(&out).unwrap()).unwrap();
        let ops = v.get("ops").as_arr().unwrap();
        let open = ops
            .iter()
            .find(|o| o.get("name").as_str() == Some("serve_open_loop_x1"))
            .unwrap();
        assert_eq!(open.get("extras").get("goodput_req_s").as_f64(), Some(9.5));
        assert_eq!(open.get("median_ns").as_f64(), Some(2.0e6));
        // empty extras objects are dropped from the artifact
        let fig = ops
            .iter()
            .find(|o| o.get("name").as_str() == Some("flare_n1024_m64"))
            .unwrap();
        assert_eq!(fig.get("extras"), &Json::Null);
        assert_eq!(check(&out).unwrap(), 2);
    }

    #[test]
    fn check_rejects_open_loop_ops_missing_contract_fields() {
        let dir = tmp("check_open");
        write_dump(
            &dir,
            "serve_open_loop",
            r#"[{"name": "serve_open_loop_x2", "iters": 10, "p50_ms": 2.0,
                 "extras": {"goodput_req_s": 9.5, "load_factor": 2.0}}]"#,
        );
        let out = dir.join("BENCH_native.json");
        let err = fold(&[dir.clone()], &out, 4, "abc").unwrap_err().to_string();
        assert!(err.contains("p99_ms"), "validator names the missing field: {err}");
    }

    #[test]
    fn check_rejects_schema_violations() {
        let dir = tmp("check_bad");
        let p = dir.join("x.json");
        for (body, needle) in [
            (r#"{"schema": 2, "backend": "native", "git_sha": "s", "threads": 4,
                 "ops": [{"bench": "b", "name": "n", "median_ns": 1, "iters": 1}]}"#, "schema"),
            (r#"{"schema": 1, "backend": "native", "git_sha": "s", "threads": 4,
                 "ops": []}"#, "non-empty"),
            (r#"{"schema": 1, "backend": "native", "git_sha": "s", "threads": 4,
                 "ops": [{"bench": "b", "name": "n", "median_ns": -5, "iters": 1}]}"#,
             "median_ns"),
            (r#"{"schema": 1, "backend": "native", "git_sha": "", "threads": 4,
                 "ops": [{"bench": "b", "name": "n", "median_ns": 1, "iters": 1}]}"#, "git_sha"),
        ] {
            std::fs::write(&p, body).unwrap();
            let err = check(&p).unwrap_err().to_string();
            assert!(err.contains(needle), "expected {needle:?} in: {err}");
        }
    }

    #[test]
    fn compare_gates_on_shared_ops_only() {
        let dir = tmp("compare");
        let base = dir.join("base.json");
        std::fs::write(
            &base,
            r#"{"schema": 1, "backend": "native", "git_sha": "s", "threads": 4, "ops": [
                 {"bench": "b", "name": "fast", "median_ns": 1000, "iters": 5},
                 {"bench": "b", "name": "other", "median_ns": 1000, "iters": 5}]}"#,
        )
        .unwrap();
        let ok = vec![mop("b", "fast", 1400.0)];
        compare(&ok, &base, 1.5).unwrap();
        let slow = vec![mop("b", "fast", 2000.0)];
        let err = compare(&slow, &base, 1.5).unwrap_err().to_string();
        assert!(err.contains("regressed"), "{err}");
        // nothing shared -> the gate must fail loudly, not silently pass
        let disjoint = vec![mop("b", "new_op", 10.0)];
        let err = compare(&disjoint, &base, 1.5).unwrap_err().to_string();
        assert!(err.contains("compared 0 ops"), "{err}");
    }

    #[test]
    fn compare_gates_memory_columns() {
        let dir = tmp("compare_mem");
        let base = dir.join("base.json");
        std::fs::write(
            &base,
            r#"{"schema": 1, "backend": "native", "git_sha": "s", "threads": 4, "ops": [
                 {"bench": "fig5_million", "name": "fig5_n65536", "median_ns": 1e9, "iters": 3,
                  "extras": {"peak_rss_gb": 0.5, "bytes_per_token": 8000}}]}"#,
        )
        .unwrap();
        // within bound on time and both memory columns: pass
        let ok = vec![mop_mem(
            "fig5_million",
            "fig5_n65536",
            1.2e9,
            &[("peak_rss_gb", 0.6), ("bytes_per_token", 9000.0)],
        )];
        compare(&ok, &base, 1.5).unwrap();
        // memory regression past the bound fails even with time flat
        let fat = vec![mop_mem(
            "fig5_million",
            "fig5_n65536",
            1.0e9,
            &[("peak_rss_gb", 0.9), ("bytes_per_token", 8000.0)],
        )];
        let err = compare(&fat, &base, 1.5).unwrap_err().to_string();
        assert!(err.contains("regressed"), "{err}");
        // a dropped memory column fails: silence must not read as a pass
        let silent = vec![mop_mem(
            "fig5_million",
            "fig5_n65536",
            1.0e9,
            &[("peak_rss_gb", 0.5)],
        )];
        let err = compare(&silent, &base, 1.5).unwrap_err().to_string();
        assert!(err.contains("did not report"), "{err}");
    }

    #[test]
    fn check_rejects_fig5_ops_missing_memory_contract() {
        let dir = tmp("check_fig5");
        write_dump(
            &dir,
            "fig5_million",
            r#"[{"name": "fig5_n65536", "iters": 3, "p50_ms": 1000.0,
                 "extras": {"peak_rss_gb": 0.5}}]"#,
        );
        let out = dir.join("BENCH_native.json");
        let err = fold(&[dir.clone()], &out, 4, "abc").unwrap_err().to_string();
        assert!(err.contains("bytes_per_token"), "validator names the missing field: {err}");
    }

    #[test]
    fn calibrate_rewrites_baseline_with_provenance() {
        let dir = tmp("calibrate");
        let native = dir.join("BENCH_native.json");
        std::fs::write(
            &native,
            r#"{"schema": 1, "backend": "native", "git_sha": "deadbeef", "threads": 4, "ops": [
                 {"bench": "serve_open_loop", "name": "serve_open_loop_x1", "median_ns": 5e6,
                  "iters": 10,
                  "extras": {"goodput_req_s": 9.0, "load_factor": 1.0, "p99_ms": 7.0}},
                 {"bench": "fig2_scaling", "name": "flare_n1024_m64", "median_ns": 2e6,
                  "iters": 5}]}"#,
        )
        .unwrap();
        let baseline = dir.join("BENCH_baseline.json");
        assert_eq!(calibrate(&native, &baseline).unwrap(), 2);
        let v = parse(&std::fs::read_to_string(&baseline).unwrap()).unwrap();
        assert_eq!(v.get("schema").as_usize(), Some(1));
        assert_eq!(v.get("git_sha").as_str(), Some("deadbeef"));
        let note = v.get("note").as_str().unwrap();
        assert!(note.contains("deadbeef"), "provenance names the source sha: {note}");
        let ops = v.get("ops").as_arr().unwrap();
        assert_eq!(ops.len(), 2);
        // baseline ops are stripped to exactly what compare() reads
        assert_eq!(ops[0].get("extras"), &Json::Null);
        // and the result must be usable as a compare() baseline
        let m = vec![mop("fig2_scaling", "flare_n1024_m64", 2.5e6)];
        compare(&m, &baseline, 1.5).unwrap();
    }

    #[test]
    fn calibrate_preserves_gated_memory_columns() {
        let dir = tmp("calibrate_mem");
        let native = dir.join("BENCH_native.json");
        std::fs::write(
            &native,
            r#"{"schema": 1, "backend": "native", "git_sha": "cafe", "threads": 4, "ops": [
                 {"bench": "fig5_million", "name": "fig5_n65536", "median_ns": 1e9, "iters": 3,
                  "extras": {"peak_rss_gb": 0.5, "bytes_per_token": 8000, "n": 65536}}]}"#,
        )
        .unwrap();
        let baseline = dir.join("BENCH_baseline.json");
        assert_eq!(calibrate(&native, &baseline).unwrap(), 1);
        let v = parse(&std::fs::read_to_string(&baseline).unwrap()).unwrap();
        let op = &v.get("ops").as_arr().unwrap()[0];
        // gated memory keys survive calibration; incidental extras do not
        assert_eq!(op.get("extras").get("peak_rss_gb").as_f64(), Some(0.5));
        assert_eq!(op.get("extras").get("bytes_per_token").as_f64(), Some(8000.0));
        assert_eq!(op.get("extras").get("n"), &Json::Null);
        // and the memory gate reads it back
        let fat = vec![mop_mem(
            "fig5_million",
            "fig5_n65536",
            1.0e9,
            &[("peak_rss_gb", 1.0), ("bytes_per_token", 8000.0)],
        )];
        assert!(compare(&fat, &baseline, 1.5).is_err());
    }
}
