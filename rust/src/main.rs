//! `flare` — CLI entrypoint for the FLARE reproduction.
//!
//! Subcommands:
//!   info                         manifest + artifact summary
//!   gen-data   --dataset <name>  run a simulator, print dataset statistics
//!   train      --case <name>     train a case end-to-end, report metrics
//!   serve      --case <name>     serving engine: demo load, or an HTTP
//!                                front end with --addr (drains on SIGTERM)
//!   serve-bench                  closed-loop latency bench; --open-loop
//!                                runs the socket-level overload bench
//!   spectra    --case <name>     Algorithm-1 eigenanalysis of a model
//!   bench-report                 fold results/*.json into BENCH_native.json
//!                                (--check validates, --calibrate refreshes
//!                                BENCH_baseline.json)
//!
//! Without an `artifacts/manifest.json`, commands fall back to the builtin
//! CPU-sized cases and the native backend trains them directly — a clean
//! checkout can run `cargo run -- train` end to end.
//!
//! Global options:
//!   --artifacts <dir>   (default ./artifacts or $FLARE_ARTIFACTS)
//!   --backend <name>    native | xla (default: xla when compiled in, else
//!                       native; $FLARE_BACKEND overrides)

use flare::cli::Args;
use flare::config::{Manifest, Precision};
use flare::coordinator::{Server, ServerConfig};
use flare::data;
use flare::model::{find_entry, init_params, param_slice};
use flare::runtime::{default_backend, make_backend, Backend};
use flare::spectral::{eig_lowrank, spectra_diversity, HeadSpectrum};
use flare::train::{train_case, TrainOpts};
use flare::util::stats::Timer;

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn manifest_dir(args: &Args) -> std::path::PathBuf {
    args.get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(Manifest::default_dir)
}

/// `--precision f32|bf16|int8`: serve-time tier override (None = per-case).
fn precision_from_args(args: &Args) -> anyhow::Result<Option<Precision>> {
    match args.get("precision") {
        Some(s) => Ok(Some(Precision::parse(s)?)),
        None => Ok(None),
    }
}

fn backend_from_args(args: &Args) -> anyhow::Result<Box<dyn Backend>> {
    match args.get("backend") {
        Some(kind) => make_backend(kind),
        None => default_backend(),
    }
}

fn run(args: &Args) -> anyhow::Result<()> {
    match args.subcommand.as_str() {
        "info" => cmd_info(args),
        "gen-data" => cmd_gen_data(args),
        "train" => cmd_train(args),
        "serve" => cmd_serve(args),
        "serve-bench" => cmd_serve_bench(args),
        "spectra" => cmd_spectra(args),
        "bench-report" => cmd_bench_report(args),
        "" | "help" => {
            print_help();
            Ok(())
        }
        other => {
            print_help();
            anyhow::bail!("unknown subcommand {other:?}")
        }
    }
}

fn print_help() {
    println!(
        "flare — FLARE: Fast Low-rank Attention Routing Engine (reproduction)\n\
         \n\
         USAGE: flare <subcommand> [options]\n\
         \n\
         SUBCOMMANDS\n\
           info                        manifest + artifact summary\n\
           gen-data --dataset <name>   run a simulator, print statistics\n\
                    [--count K] [--stats]\n\
           train    [--case <name>]    train end-to-end (any backend;\n\
                    default case core_darcy_flare)\n\
                    [--steps N] [--eval-every K] [--ckpt FILE] [--quiet]\n\
                    [--resume FILE]    continue from a --ckpt checkpoint\n\
                    [--accum K]        sum gradients over K micro-batches\n\
                                       per optimizer step (native backend)\n\
                    [--ckpt-every K]   also write --ckpt every K steps\n\
                                       (atomic + CRC32; previous file kept\n\
                                       as FILE.bak — --resume falls back)\n\
                    [--max-nonfinite K] abort after K consecutive NaN/inf\n\
                                       steps (skipped, params kept; def 3)\n\
                    [--ranks K]        K-process data-parallel training\n\
                                       (power of two; native backend; the\n\
                                       summed gradient — and checkpoint —\n\
                                       is bitwise identical at any K;\n\
                                       $FLARE_COMMS shm|tcp transport)\n\
                    [--logical-shards S] fixed gradient-reduction shard\n\
                                       count (power of two, default 64;\n\
                                       $FLARE_LOGICAL_SHARDS / manifest\n\
                                       'logical_shards' also set it)\n\
           serve    --case <name>      serving engine + demo load\n\
                    [--requests K] [--concurrency C]\n\
                    [--addr HOST:PORT] HTTP/1.1 front end instead of demo\n\
                                       load: POST /v1/infer, GET /healthz,\n\
                                       GET /metrics; SIGTERM/ctrl-c drains\n\
                    [--cases a,b,c]    serve several shape buckets\n\
                    [--handlers H] [--max-wait-ms W]\n\
                    [--max-concurrent N]        admission bound (0 = off)\n\
                    [--waiting-served-ratio R]  eager-flush ratio (0 = off)\n\
                    [--precision f32|bf16|int8] inference tier override\n\
                    [--panic-trip K]   engine_dead after K consecutive\n\
                                       backend panics (0 = off, default 3)\n\
           serve-bench                 closed-loop serving load generator:\n\
                    [--case <name>] [--requests K] [--concurrency C]\n\
                    [--max-wait-ms W] [--quiet] [--quick]\n\
                    [--precision f32|bf16|int8] tier override; tags the\n\
                                       measurement (serve_closed_loop_int8_*)\n\
                                       p50/p99 latency + req/s, dumped into\n\
                                       results/serve_bench.json for\n\
                                       bench-report ($FLARE_BENCH_QUICK=1\n\
                                       matches --quick)\n\
                    [--open-loop]      overload bench over real sockets:\n\
                                       fixed arrival rates at 0.5x/1x/2x of\n\
                                       probed capacity; goodput + p50/p99 +\n\
                                       429 counts per load factor, dumped\n\
                                       into results/serve_open_loop.json\n\
                    [--max-concurrent N]  admission bound for --open-loop\n\
           spectra  --case <name>      eigenanalysis (paper Algorithm 1)\n\
                    [--steps N]\n\
           bench-report               fold results/*.json benchmark dumps\n\
                    [--results DIR] [--out FILE]   into BENCH_native.json\n\
                    [--compare BASELINE.json [--max-regression R]]\n\
                                       exit non-zero when any shared op's\n\
                                       median ns/op regresses past R (1.5)\n\
                    [--check FILE]     validate a BENCH artifact's schema\n\
                                       (replaces the old jq probes in CI)\n\
                    [--calibrate BENCH_native.json [--out BASELINE]]\n\
                                       rewrite BENCH_baseline.json from a\n\
                                       fresh run, stamping provenance\n\
         \n\
         GLOBAL: --artifacts <dir>     artifacts directory (missing manifest\n\
                                       falls back to builtin native cases)\n\
                 --backend <name>      native | xla ($FLARE_BACKEND)\n\
                 $FLARE_FAILPOINTS     chaos fault injection, e.g.\n\
                                       'native.forward_batch=1*panic'\n\
                                       (see README Operations)\n"
    );
}

fn cmd_info(args: &Args) -> anyhow::Result<()> {
    let m = Manifest::load_or_builtin(manifest_dir(args))?;
    println!("artifacts dir : {:?}", m.dir);
    println!("seed          : {}", m.seed);
    println!("cases         : {}", m.cases.len());
    println!("mixer artifacts: {}", m.mixers.len());
    println!("layer artifacts: {}", m.layers.len());
    let mut groups: std::collections::BTreeMap<&str, usize> = Default::default();
    for c in &m.cases {
        *groups.entry(c.group.as_str()).or_default() += 1;
    }
    for (g, n) in groups {
        println!("  group {g:<8} {n} cases");
    }
    Ok(())
}

fn cmd_gen_data(args: &Args) -> anyhow::Result<()> {
    let m = Manifest::load_or_builtin(manifest_dir(args))?;
    let name = args.get_or("dataset", "darcy").to_string();
    let count = args.get_usize("count")?.unwrap_or(4);
    // find a case that uses this dataset to get its metadata
    let case = m
        .cases
        .iter()
        .find(|c| c.dataset == name)
        .ok_or_else(|| anyhow::anyhow!("no case uses dataset {name:?}"))?;
    let mut meta = case.dataset_meta.clone();
    if let flare::util::json::Json::Obj(ref mut o) = meta {
        o.insert("train".into(), flare::util::json::Json::num(count as f64));
        o.insert("test".into(), flare::util::json::Json::num(1.0));
    }
    let t = Timer::start();
    let ds = data::build(&name, &meta, m.seed)?;
    println!(
        "generated {} train + {} test samples of {:?} in {:.2}s",
        ds.train_len(),
        ds.test_len(),
        name,
        t.elapsed_s()
    );
    if ds.is_classification() {
        let mut counts = std::collections::BTreeMap::new();
        for s in &ds.train_tokens {
            *counts.entry(s.label).or_insert(0usize) += 1;
        }
        println!("n = {} tokens/sample, label histogram: {counts:?}", ds.n);
    } else {
        println!("n = {} points, d_in = {}, d_out = {}", ds.n, ds.d_in, ds.d_out);
        let ys: Vec<f64> = ds
            .train_fields
            .iter()
            .flat_map(|s| s.y.iter().map(|&v| v as f64))
            .collect();
        let stats = flare::util::stats::Summary::of(&ys);
        println!(
            "target field: mean {:.4} std {:.4} min {:.4} max {:.4}",
            stats.mean, stats.std, stats.min, stats.max
        );
    }
    if args.has_flag("stats") && name == "lpbf" {
        // Table-6-style part statistics
        println!("\nLPBF part statistics (Table 6 analogue, 10 parts):");
        let mut rng = flare::util::rng::Rng::new(m.seed);
        println!(
            "{:>8} {:>8} {:>12} {:>14}",
            "points", "edges", "height(mm)", "max |disp|"
        );
        for _ in 0..10 {
            let st = data::lpbf::stats(&mut rng, 4096);
            println!(
                "{:>8} {:>8} {:>12.1} {:>14.4}",
                st.points, st.edges, st.max_height_mm, st.max_displacement
            );
        }
    }
    Ok(())
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let m = Manifest::load_or_builtin(manifest_dir(args))?;
    let name = args.get_or("case", "core_darcy_flare").to_string();
    let case = m.case(&name)?;
    let ranks = args.get_usize("ranks")?.unwrap_or(1).max(1);
    let logical_shards =
        flare::config::resolve_logical_shards(args.get_usize("logical-shards")?, m.logical_shards)?;
    // publish the resolved count so backends built below (and worker ranks,
    // which inherit the environment) all cut the same reduction tree
    std::env::set_var("FLARE_LOGICAL_SHARDS", logical_shards.to_string());

    // worker re-entry: this process is rank >= 1 of a `train --ranks K` job
    if let Some(w) = flare::train::dp::worker_env()? {
        let ex = flare::util::comms::WorkerExchange::connect(
            &w.addr,
            &w.session,
            w.rank,
            w.ranks,
            case.param_count,
        )
        .map_err(|e| anyhow::anyhow!("rank {} rendezvous: {e}", w.rank))?;
        let backend = flare::runtime::NativeBackend::new()
            .with_logical_shards(logical_shards)
            .with_dp(w.rank, w.ranks, Box::new(ex));
        return run_train(args, &m, &name, &backend, Some((w.rank, w.ranks)));
    }

    if ranks > 1 {
        anyhow::ensure!(
            args.get("backend").map_or(true, |b| b == "native"),
            "--ranks needs the native backend (got --backend {:?})",
            args.get("backend").unwrap_or_default()
        );
        // must run before the first thread-pool touch so rank 0's
        // per-rank thread budget can still be pinned
        let (layout, exchange, mut set) =
            flare::train::dp::launch(ranks, logical_shards, case.param_count)?;
        let backend = flare::runtime::NativeBackend::new()
            .with_logical_shards(layout.logical_shards)
            .with_dp(0, ranks, Box::new(exchange));
        return match run_train(args, &m, &name, &backend, Some((0, ranks))) {
            Ok(()) => set.wait_all(),
            Err(e) => Err(set.fail(e)),
        };
    }

    let backend = backend_from_args(args)?;
    run_train(args, &m, &name, backend.as_ref(), None)
}

/// The body of `train`: parse the training options, run
/// [`train_case`], print the report and write the final checkpoint.
/// Under `--ranks K` this runs on every rank with `dp = Some((rank, K))`;
/// worker ranks stay silent and never write the checkpoint.
fn run_train(
    args: &Args,
    m: &Manifest,
    name: &str,
    backend: &dyn Backend,
    dp: Option<(usize, usize)>,
) -> anyhow::Result<()> {
    let case = m.case(name)?;
    let is_worker = dp.is_some_and(|(rank, _)| rank > 0);
    let resume = match args.get("resume") {
        Some(path) => {
            // a torn/corrupted primary falls back to the `.bak` rotation
            // the atomic saver keeps (warning printed when that happens)
            let (ck, from_bak) = flare::model::load_checkpoint_or_backup(path)?;
            if from_bak && !is_worker {
                println!(
                    "warning: checkpoint {path} failed verification; resuming from {}",
                    flare::model::checkpoint::backup_path(path).display()
                );
            }
            anyhow::ensure!(
                ck.case == name,
                "checkpoint {path:?} was written for case {:?}, not {name:?}",
                ck.case
            );
            let len = ck.params.len();
            // legacy params-only checkpoints (empty moments) resume with
            // zeros; any other length is corruption, not legacy
            anyhow::ensure!(
                (ck.m.len() == len && ck.v.len() == len) || (ck.m.is_empty() && ck.v.is_empty()),
                "checkpoint {path:?} moment lengths {}/{} do not match {len} params",
                ck.m.len(),
                ck.v.len()
            );
            let mom = if ck.m.is_empty() { vec![0.0; len] } else { ck.m };
            let vel = if ck.v.is_empty() { vec![0.0; len] } else { ck.v };
            if !is_worker {
                println!("resuming from {path} at step {}", ck.step);
            }
            Some((
                flare::runtime::OptState {
                    params: ck.params,
                    m: mom,
                    v: vel,
                },
                ck.step,
            ))
        }
        None => None,
    };
    let accum = args.get_usize("accum")?.unwrap_or(1).max(1);
    let ckpt_every = args.get_usize("ckpt-every")?.unwrap_or(0);
    anyhow::ensure!(
        ckpt_every == 0 || args.get("ckpt").is_some(),
        "--ckpt-every needs --ckpt FILE to know where to write"
    );
    let opts = TrainOpts {
        steps: args.get_usize("steps")?,
        eval_every: args.get_usize("eval-every")?.unwrap_or(0),
        sample_seed: 0x5EED,
        log_every: if args.has_flag("quiet") { 0 } else { 25 },
        resume,
        accum,
        ckpt_every,
        ckpt_path: args.get("ckpt").map(std::path::PathBuf::from),
        max_nonfinite: args.get_usize("max-nonfinite")?.unwrap_or(3),
        dp,
    };
    if !is_worker {
        println!(
            "training {name} on {} backend: {} params, dataset {}, batch {}{}",
            backend.name(),
            case.param_count,
            case.dataset,
            case.batch,
            if accum > 1 {
                format!(" (x{accum} accumulated = {} effective)", accum * case.batch)
            } else {
                String::new()
            }
        );
    }
    let out = train_case(backend, m, case, &opts)?;
    if is_worker {
        return Ok(()); // artifacts and reporting are rank 0's job
    }
    println!(
        "done: {} steps in {:.1}s ({:.1} ms/step p50 {:.1})",
        out.steps, out.wall_s, out.step_ms.mean, out.step_ms.p50
    );
    println!(
        "first/last loss: {:.4} -> {:.4}; final test metric: {:.5}",
        out.losses.first().copied().unwrap_or(f64::NAN),
        out.losses.last().copied().unwrap_or(f64::NAN),
        out.final_metric
    );
    if out.skipped_steps > 0 {
        println!(
            "warning: {} optimizer step(s) skipped by the non-finite guard",
            out.skipped_steps
        );
    }
    if let Some(path) = args.get("ckpt") {
        flare::model::save_checkpoint(
            path,
            &flare::model::Checkpoint {
                case: out.case.clone(),
                step: out.steps,
                params: out.params.clone(),
                m: out.opt_m.clone(),
                v: out.opt_v.clone(),
                train_loss: out.losses.last().copied().unwrap_or(0.0),
            },
        )?;
        println!("checkpoint written to {path} (full optimizer state; resume with --resume)");
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let dir = manifest_dir(args);
    let m = Manifest::load_or_builtin(&dir)?;
    // --cases a,b,c serves several shape buckets; --case serves one
    let cases: Vec<String> = match args.get("cases") {
        Some(list) => list
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect(),
        None => vec![args.get_or("case", "core_darcy_flare").to_string()],
    };
    anyhow::ensure!(!cases.is_empty(), "--cases must name at least one case");
    for c in &cases {
        m.case(c)?;
    }
    let cfg = ServerConfig {
        cases: cases.clone(),
        max_wait: std::time::Duration::from_millis(
            args.get_usize("max-wait-ms")?.unwrap_or(10) as u64
        ),
        params: vec![],
        backend: args.get("backend").map(str::to_string),
        max_concurrent: args.get_usize("max-concurrent")?.unwrap_or(0),
        waiting_served_ratio: args.get_f64("waiting-served-ratio")?.unwrap_or(0.0),
        precision: precision_from_args(args)?,
        panic_trip_threshold: args.get_usize("panic-trip")?.unwrap_or(3),
    };

    if let Some(addr) = args.get("addr") {
        // network mode: HTTP/1.1 front end, drained on SIGTERM/ctrl-c
        let server = Server::start(dir, cfg)?;
        let http = flare::coordinator::HttpServer::start(
            server,
            flare::coordinator::HttpConfig {
                addr: addr.to_string(),
                handlers: args.get_usize("handlers")?.unwrap_or(4).max(1),
                limits: flare::coordinator::Limits::default(),
            },
        )?;
        println!("serving {} on http://{}", cases.join(", "), http.addr());
        println!("endpoints: POST /v1/infer, GET /healthz, GET /metrics");
        let stop = flare::coordinator::http::shutdown_flag();
        while !stop.load(std::sync::atomic::Ordering::SeqCst) {
            std::thread::sleep(std::time::Duration::from_millis(100));
        }
        println!("signal received: draining (in-flight finish; new requests get 503)");
        http.shutdown()?;
        println!("drained cleanly");
        return Ok(());
    }

    let name = cases[0].clone();
    let case = m.case(&name)?.clone();
    let requests = args.get_usize("requests")?.unwrap_or(16);
    let concurrency = args.get_usize("concurrency")?.unwrap_or(4).max(1);

    println!(
        "starting server for {name} (n={}, batch={})",
        case.model.n, case.batch
    );
    let server = Server::start(dir, cfg)?;
    let ds = data::build(&case.dataset, &case.dataset_meta, m.seed)?;
    let t = Timer::start();
    std::thread::scope(|scope| {
        for w in 0..concurrency {
            let server = &server;
            let ds = &ds;
            let case = &case;
            scope.spawn(move || {
                for i in 0..requests / concurrency {
                    let s = &ds.test_fields[(w + i) % ds.test_len()];
                    let resp = server.infer(s.x.clone(), case.model.n).expect("infer");
                    assert_eq!(resp.y.len(), case.model.n * case.model.d_out);
                }
            });
        }
    });
    let wall = t.elapsed_s();
    let served = (requests / concurrency) * concurrency;
    println!(
        "served {served} requests in {wall:.2}s ({:.1} req/s)",
        served as f64 / wall
    );
    println!("{}", server.metrics.report());
    server.shutdown()?;
    Ok(())
}

/// Closed-loop serving load generator: `--concurrency` client threads each
/// issue blocking `infer` calls back to back against the serving engine and
/// record end-to-end latency.  Reports p50/p99 latency and req/s, and dumps
/// a bench measurement into `results/serve_bench.json` so `bench-report`
/// folds serving into `BENCH_native.json` (and the CI perf gate covers it
/// via the `serve_bench` entries in `BENCH_baseline.json`).
fn cmd_serve_bench(args: &Args) -> anyhow::Result<()> {
    use std::sync::Mutex;
    if args.has_flag("open-loop") {
        return cmd_serve_bench_open_loop(args);
    }
    let dir = manifest_dir(args);
    let m = Manifest::load_or_builtin(&dir)?;
    let name = args.get_or("case", "core_darcy_flare").to_string();
    let case = m.case(&name)?.clone();
    let quick = args.has_flag("quick") || flare::bench::quick_mode();
    let concurrency = args.get_usize("concurrency")?.unwrap_or(4).max(1);
    let requests = args
        .get_usize("requests")?
        .unwrap_or(if quick { 16 } else { 64 })
        .max(concurrency);
    let max_wait = args.get_usize("max-wait-ms")?.unwrap_or(5);
    let precision = precision_from_args(args)?;
    // tier-tagged measurement name so the baseline gate tracks each
    // precision tier as its own op (serve_closed_loop_int8_c4 etc.)
    let tier_tag = match precision {
        Some(p) if p != Precision::F32 => format!("{}_", p.as_str()),
        _ => String::new(),
    };
    // spread the load exactly: the first `requests % concurrency` clients
    // issue one extra request, so nothing is silently dropped to rounding
    let base = requests / concurrency;
    let extra = requests % concurrency;

    println!(
        "serve-bench: {name} (n={}, batch={}), {concurrency} clients, {requests} requests, \
         max_wait {max_wait}ms{}",
        case.model.n,
        case.batch,
        match precision {
            Some(p) => format!(", precision {}", p.as_str()),
            None => String::new(),
        }
    );
    let server = Server::start(
        dir,
        ServerConfig {
            cases: vec![name.clone()],
            max_wait: std::time::Duration::from_millis(max_wait as u64),
            params: vec![],
            backend: args.get("backend").map(str::to_string),
            precision,
            ..ServerConfig::default()
        },
    )?;

    let x = vec![0.25f32; case.model.n * case.model.d_in];
    // warmup: fill the per-bucket workspaces and the worker-local pools so
    // the timed window measures the steady state
    for _ in 0..2usize.max(case.batch) {
        server.infer(x.clone(), case.model.n)?;
    }

    let latencies_ms: Mutex<Vec<f64>> = Mutex::new(Vec::with_capacity(requests));
    // (total retry attempts, requests that needed at least one retry)
    let retry_counts: Mutex<(u64, u64)> = Mutex::new((0, 0));
    let wall = Timer::start();
    std::thread::scope(|scope| {
        for cidx in 0..concurrency {
            let server = &server;
            let x = &x;
            let latencies_ms = &latencies_ms;
            let retry_counts = &retry_counts;
            let n = case.model.n;
            let my_requests = base + usize::from(cidx < extra);
            scope.spawn(move || {
                let mut rng = flare::util::rng::Rng::new(0xC11E47 ^ cidx as u64);
                let mut local = Vec::with_capacity(my_requests);
                let (mut my_retries, mut my_retried) = (0u64, 0u64);
                for _ in 0..my_requests {
                    let t = Timer::start();
                    let (resp, tries) =
                        infer_with_retry(server, x, n, &mut rng).expect("infer");
                    assert_eq!(resp.y.len(), n * case.model.d_out);
                    local.push(t.elapsed_ms());
                    if tries > 0 {
                        my_retried += 1;
                        my_retries += tries as u64;
                    }
                }
                latencies_ms.lock().unwrap().extend_from_slice(&local);
                let mut rc = retry_counts.lock().unwrap();
                rc.0 += my_retries;
                rc.1 += my_retried;
            });
        }
    });
    let wall_s = wall.elapsed_s();
    let latencies = latencies_ms.into_inner().unwrap();
    let (retries_total, retried_requests) = retry_counts.into_inner().unwrap();
    let served = latencies.len();
    let summary = flare::util::stats::Summary::of(&latencies);
    let req_per_s = served as f64 / wall_s;
    println!(
        "served {served} requests in {wall_s:.2}s: {req_per_s:.1} req/s, \
         p50 {:.2} ms, p95 {:.2} ms, p99 {:.2} ms",
        summary.p50, summary.p95, summary.p99
    );
    if !args.has_flag("quiet") {
        println!("{}", server.metrics.report());
    }
    server.shutdown()?;

    let measurement = flare::bench::Measurement {
        name: format!("serve_closed_loop_{tier_tag}c{concurrency}"),
        iters: served,
        total_s: wall_s,
        per_iter: summary.clone(),
        extras: vec![
            ("req_per_s".into(), req_per_s),
            ("p99_ms".into(), summary.p99),
            ("clients".into(), concurrency as f64),
            ("max_wait_ms".into(), max_wait as f64),
            // distinguish goodput from retried work in overload runs
            ("retries".into(), retries_total as f64),
            ("retried_requests".into(), retried_requests as f64),
        ],
    };
    // tier-tagged dump file so an int8 run folded in the same results dir
    // does not clobber the f32 serve_bench.json (bench-report folds both)
    let dump = if tier_tag.is_empty() {
        "serve_bench".to_string()
    } else {
        format!("serve_bench_{}", tier_tag.trim_end_matches('_'))
    };
    let path = flare::bench::save_results(&dump, &[measurement])?;
    println!("results written to {path:?}");
    Ok(())
}

/// Closed-loop client with bounded retry: retriable rejections — admission
/// 429s and recovered backend panics, the classes the HTTP edge tags with
/// `Retry-After` — back off exponentially with deterministic jitter and go
/// again (at most 5 times); everything else fails immediately.  Returns
/// the response plus how many retries it took.  Backoff is ms-scale: the
/// edge's `Retry-After: 1` is pacing for remote clients, while in-process
/// queue turnover is milliseconds.
fn infer_with_retry(
    server: &Server,
    x: &Vec<f32>,
    n: usize,
    rng: &mut flare::util::rng::Rng,
) -> anyhow::Result<(flare::coordinator::Response, usize)> {
    use flare::coordinator::{ReplyError, SubmitError};
    const MAX_RETRIES: usize = 5;
    let mut retries = 0usize;
    loop {
        let err: Box<dyn std::fmt::Display> = match server.try_submit(None, x.clone(), n, None) {
            Ok(rx) => match rx.recv() {
                Ok(Ok(resp)) => return Ok((resp, retries)),
                Ok(Err(e @ ReplyError::BackendPanic { .. })) => Box::new(e),
                Ok(Err(e)) => anyhow::bail!("{e}"),
                Err(_) => anyhow::bail!("server dropped request"),
            },
            Err(e @ SubmitError::Admission { .. }) => Box::new(e),
            Err(e) => anyhow::bail!("{e}"),
        };
        anyhow::ensure!(retries < MAX_RETRIES, "{err} (gave up after {MAX_RETRIES} retries)");
        retries += 1;
        let base_ms = 1u64 << (retries - 1).min(6);
        let jitter = rng.below(base_ms as usize + 1) as u64;
        std::thread::sleep(std::time::Duration::from_millis(base_ms + jitter));
    }
}

/// One blocking HTTP request against the serving front end; returns the
/// status code.  `Connection: close` so read-to-EOF frames the response.
fn http_post_infer(addr: std::net::SocketAddr, body: &str) -> anyhow::Result<u16> {
    use std::io::{Read, Write};
    let mut s = std::net::TcpStream::connect(addr)?;
    s.set_nodelay(true)?;
    let req = format!(
        "POST /v1/infer HTTP/1.1\r\nHost: flare\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    s.write_all(req.as_bytes())?;
    let mut resp = String::new();
    s.read_to_string(&mut resp)?;
    resp.strip_prefix("HTTP/1.1 ")
        .and_then(|r| r.split(' ').next())
        .and_then(|c| c.parse::<u16>().ok())
        .ok_or_else(|| {
            anyhow::anyhow!("malformed HTTP response: {:?}", &resp[..resp.len().min(64)])
        })
}

/// Open-loop overload bench over real sockets (the closed-loop bench above
/// can never overload the engine — each client waits for its reply).  A
/// short closed-loop probe estimates capacity, then fixed Poisson-free
/// arrival schedules at 0.5x/1x/2x of that capacity are replayed by sender
/// threads; latency is measured from the *scheduled* arrival time, so
/// queueing delay under overload is visible, and 429 rejections count
/// against goodput rather than hanging the run.
fn cmd_serve_bench_open_loop(args: &Args) -> anyhow::Result<()> {
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Mutex;
    use std::time::{Duration, Instant};
    let dir = manifest_dir(args);
    let m = Manifest::load_or_builtin(&dir)?;
    let name = args.get_or("case", "core_darcy_flare").to_string();
    let case = m.case(&name)?.clone();
    let quick = args.has_flag("quick") || flare::bench::quick_mode();
    let max_wait = args.get_usize("max-wait-ms")?.unwrap_or(5);
    let per_factor = args
        .get_usize("requests")?
        .unwrap_or(if quick { 32 } else { 160 })
        .max(8);
    let senders = if quick { 8 } else { 16 };
    // admission bound: one accumulating batch + one executing, so overload
    // turns into fast 429s instead of an unbounded queue
    let max_concurrent = args
        .get_usize("max-concurrent")?
        .unwrap_or(2 * case.max_batch.max(case.batch))
        .max(1);

    let server = Server::start(
        dir,
        ServerConfig {
            cases: vec![name.clone()],
            max_wait: Duration::from_millis(max_wait as u64),
            params: vec![],
            backend: args.get("backend").map(str::to_string),
            max_concurrent,
            waiting_served_ratio: args.get_f64("waiting-served-ratio")?.unwrap_or(0.0),
            precision: precision_from_args(args)?,
            ..ServerConfig::default()
        },
    )?;
    let http = flare::coordinator::HttpServer::start(
        server,
        flare::coordinator::HttpConfig {
            addr: "127.0.0.1:0".to_string(),
            handlers: senders,
            limits: flare::coordinator::Limits::default(),
        },
    )?;
    let addr = http.addr();
    println!(
        "serve-bench --open-loop: {name} (n={}, batch={}, max_batch={}) on http://{addr}, \
         max_concurrent {max_concurrent}, {per_factor} requests per load factor",
        case.model.n, case.batch, case.max_batch
    );
    let numbers = vec!["0.25"; case.model.n * case.model.d_in].join(",");
    let body = format!("{{\"x\": [{numbers}], \"n\": {}}}", case.model.n);

    // capacity estimate: a short closed-loop burst over the same socket path
    let probe_clients = 4usize;
    let probe = (if quick { 12 } else { 32 }) / probe_clients;
    for _ in 0..2usize.max(case.batch) {
        anyhow::ensure!(http_post_infer(addr, &body)? == 200, "warmup infer failed");
    }
    let t = Timer::start();
    std::thread::scope(|scope| {
        for _ in 0..probe_clients {
            let body = &body;
            scope.spawn(move || {
                for _ in 0..probe {
                    assert_eq!(http_post_infer(addr, body).expect("probe"), 200);
                }
            });
        }
    });
    let capacity = (probe * probe_clients) as f64 / t.elapsed_s();
    println!(
        "estimated capacity {capacity:.1} req/s (closed-loop probe, {} requests)",
        probe * probe_clients
    );

    let mut measurements = Vec::new();
    for factor in [0.5, 1.0, 2.0] {
        let rate = (capacity * factor).max(1.0);
        let ok = AtomicUsize::new(0);
        let rejected = AtomicUsize::new(0);
        let failed = AtomicUsize::new(0);
        let lat_ms: Mutex<Vec<f64>> = Mutex::new(Vec::with_capacity(per_factor));
        let t0 = Instant::now();
        std::thread::scope(|scope| {
            for tid in 0..senders {
                let (body, ok, rejected, failed, lat_ms) =
                    (&body, &ok, &rejected, &failed, &lat_ms);
                scope.spawn(move || {
                    let mut local = Vec::new();
                    let mut i = tid;
                    while i < per_factor {
                        let due = t0 + Duration::from_secs_f64(i as f64 / rate);
                        if let Some(wait) = due.checked_duration_since(Instant::now()) {
                            std::thread::sleep(wait);
                        }
                        match http_post_infer(addr, body) {
                            Ok(200) => {
                                ok.fetch_add(1, Ordering::Relaxed);
                                local.push((Instant::now() - due).as_secs_f64() * 1e3);
                            }
                            Ok(429) => {
                                rejected.fetch_add(1, Ordering::Relaxed);
                            }
                            Ok(_) | Err(_) => {
                                failed.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                        i += senders;
                    }
                    lat_ms.lock().unwrap().extend_from_slice(&local);
                });
            }
        });
        let wall_s = t0.elapsed().as_secs_f64();
        let served = ok.load(Ordering::Relaxed);
        let rej = rejected.load(Ordering::Relaxed);
        let errs = failed.load(Ordering::Relaxed);
        anyhow::ensure!(errs == 0, "{errs} requests failed with non-200/429 status");
        let lat = lat_ms.into_inner().unwrap();
        let summary = flare::util::stats::Summary::of(&lat);
        let goodput = served as f64 / wall_s;
        println!(
            "x{factor}: offered {rate:.1} req/s -> goodput {goodput:.1} req/s, {rej} rejected \
             (429), p50 {:.2} ms, p99 {:.2} ms",
            summary.p50, summary.p99
        );
        measurements.push(flare::bench::Measurement {
            name: format!("serve_open_loop_x{factor}"),
            iters: served,
            total_s: wall_s,
            per_iter: summary.clone(),
            extras: vec![
                ("goodput_req_s".into(), goodput),
                ("load_factor".into(), factor),
                ("p99_ms".into(), summary.p99),
                ("offered_req_s".into(), rate),
                ("rejected_429".into(), rej as f64),
                ("requests".into(), per_factor as f64),
            ],
        });
    }
    http.shutdown()?;
    let path = flare::bench::save_results("serve_open_loop", &measurements)?;
    println!("results written to {path:?}");
    Ok(())
}

/// Bench artifact tooling, dispatching to [`flare::bench::report`]:
///   bench-report                      fold results/*.json -> BENCH_native.json
///   bench-report --compare BASE       ... then gate medians against BASE
///   bench-report --check FILE         validate an artifact's schema/contract
///   bench-report --calibrate NATIVE   rewrite BENCH_baseline.json from NATIVE
fn cmd_bench_report(args: &Args) -> anyhow::Result<()> {
    use flare::bench::report;
    if let Some(path) = args.get("check") {
        let n = report::check(std::path::Path::new(path))?;
        println!("check OK: {path} ({n} ops)");
        return Ok(());
    }
    if let Some(native) = args.get("calibrate") {
        let out = args.get_or("out", "BENCH_baseline.json").to_string();
        let n = report::calibrate(std::path::Path::new(native), std::path::Path::new(&out))?;
        println!("calibrated {out} from {native} ({n} ops)");
        return Ok(());
    }
    // default: $FLARE_RESULTS (what save_results honors), else the union of
    // ./results and rust/results — cargo run keeps the invoker's cwd while
    // cargo bench runs the dump-writing binaries from the package root, so
    // dumps can legitimately sit in either
    let dirs: Vec<std::path::PathBuf> = match args.get("results") {
        Some(d) => vec![std::path::PathBuf::from(d)],
        None => match std::env::var("FLARE_RESULTS") {
            Ok(v) => vec![std::path::PathBuf::from(v)],
            Err(_) => vec!["results".into(), "rust/results".into()],
        },
    };
    let out_path = std::path::PathBuf::from(args.get_or("out", "BENCH_native.json"));
    let threads = flare::runtime::NativeBackend::new().threads();
    let sha = std::env::var("GITHUB_SHA")
        .ok()
        .filter(|s| !s.is_empty())
        .or_else(git_head_sha)
        .unwrap_or_else(|| "unknown".to_string());
    let outcome = report::fold(&dirs, &out_path, threads, &sha)?;
    println!(
        "wrote {:?}: {} ops, {threads} threads, sha {sha}",
        outcome.path, outcome.ops
    );
    if let Some(base_path) = args.get("compare") {
        let max_reg = args.get_f64("max-regression")?.unwrap_or(1.5);
        report::compare(&outcome.measured, std::path::Path::new(base_path), max_reg)?;
    }
    Ok(())
}

fn git_head_sha() -> Option<String> {
    let out = std::process::Command::new("git")
        .args(["rev-parse", "HEAD"])
        .output()
        .ok()?;
    if !out.status.success() {
        return None;
    }
    let sha = String::from_utf8(out.stdout).ok()?.trim().to_string();
    if sha.is_empty() {
        None
    } else {
        Some(sha)
    }
}

fn cmd_spectra(args: &Args) -> anyhow::Result<()> {
    let m = Manifest::load_or_builtin(manifest_dir(args))?;
    let name = args.get_or("case", "core_elas_flare").to_string();
    let case = m.case(&name)?;
    let backend = backend_from_args(args)?;

    // optionally train first so the spectra reflect learned routing
    let steps = args.get_usize("steps")?.unwrap_or(100);
    let params_host = if steps > 0 && backend.supports_training() {
        println!("training {steps} steps first...");
        let out = train_case(
            backend.as_ref(),
            &m,
            case,
            &TrainOpts {
                steps: Some(steps),
                ..Default::default()
            },
        )?;
        println!("trained to rel-L2 {:.4}", out.final_metric);
        out.params
    } else {
        if steps > 0 {
            println!(
                "backend {:?} cannot train; analyzing the seeded init instead",
                backend.name()
            );
        }
        init_params(&case.params, case.param_count, m.seed)
    };

    // evaluate per-block keys at a test sample through the backend
    let ds = data::build(&case.dataset, &case.dataset_meta, m.seed)?;
    let sample = &ds.test_fields[0];
    let ks = backend.qk_keys(&m, case, &params_host, &sample.x)?;

    let (h, mm, d, n) = (
        case.model.heads,
        case.model.m,
        case.model.head_dim(),
        case.model.n,
    );
    println!(
        "\nSpectra (paper Fig. 12): blocks={} heads={h} M={mm} D={d} N={n}",
        case.model.blocks
    );
    for (b, kvals) in ks.iter().enumerate() {
        // kvals: [H, N, D]
        let latents = find_entry(&case.params, &format!("blk{b}.mix.latents"))?;
        let q_all = param_slice(&params_host, latents); // [H, M, D] or [M, D]
        let mut spectra = Vec::new();
        for head in 0..h {
            let q = if case.model.shared_latents {
                q_all.to_vec()
            } else {
                q_all[head * mm * d..(head + 1) * mm * d].to_vec()
            };
            let k = &kvals[head * n * d..(head + 1) * n * d];
            let eig = eig_lowrank(&q, k, mm, n, d);
            let sp = HeadSpectrum {
                block: b,
                head,
                eigenvalues: eig.eigenvalues,
            };
            let top: Vec<String> = sp.eigenvalues[..4.min(mm)]
                .iter()
                .map(|l| format!("{l:.3}"))
                .collect();
            println!(
                "  block {b} head {head}: top l [{}] eff-rank {} entropy {:.3}",
                top.join(", "),
                sp.effective_rank(1e-3),
                sp.spectral_entropy()
            );
            spectra.push(sp);
        }
        println!(
            "  block {b} spectral diversity across heads: {:.4}",
            spectra_diversity(&spectra)
        );
    }
    Ok(())
}
