//! `flare` — CLI entrypoint for the FLARE reproduction.
//!
//! Subcommands:
//!   info                         manifest + artifact summary
//!   gen-data   --dataset <name>  run a simulator, print dataset statistics
//!   train      --case <name>     train a case end-to-end, report metrics
//!   serve      --case <name>     start the serving engine, drive demo load
//!   spectra    --case <name>     Algorithm-1 eigenanalysis of a model
//!   bench-report                 fold results/*.json into BENCH_native.json
//!
//! Without an `artifacts/manifest.json`, commands fall back to the builtin
//! CPU-sized cases and the native backend trains them directly — a clean
//! checkout can run `cargo run -- train` end to end.
//!
//! Global options:
//!   --artifacts <dir>   (default ./artifacts or $FLARE_ARTIFACTS)
//!   --backend <name>    native | xla (default: xla when compiled in, else
//!                       native; $FLARE_BACKEND overrides)

use flare::cli::Args;
use flare::config::Manifest;
use flare::coordinator::{Server, ServerConfig};
use flare::data;
use flare::model::{find_entry, init_params, param_slice};
use flare::runtime::{default_backend, make_backend, Backend};
use flare::spectral::{eig_lowrank, spectra_diversity, HeadSpectrum};
use flare::train::{train_case, TrainOpts};
use flare::util::stats::Timer;

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn manifest_dir(args: &Args) -> std::path::PathBuf {
    args.get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(Manifest::default_dir)
}

fn backend_from_args(args: &Args) -> anyhow::Result<Box<dyn Backend>> {
    match args.get("backend") {
        Some(kind) => make_backend(kind),
        None => default_backend(),
    }
}

fn run(args: &Args) -> anyhow::Result<()> {
    match args.subcommand.as_str() {
        "info" => cmd_info(args),
        "gen-data" => cmd_gen_data(args),
        "train" => cmd_train(args),
        "serve" => cmd_serve(args),
        "serve-bench" => cmd_serve_bench(args),
        "spectra" => cmd_spectra(args),
        "bench-report" => cmd_bench_report(args),
        "" | "help" => {
            print_help();
            Ok(())
        }
        other => {
            print_help();
            anyhow::bail!("unknown subcommand {other:?}")
        }
    }
}

fn print_help() {
    println!(
        "flare — FLARE: Fast Low-rank Attention Routing Engine (reproduction)\n\
         \n\
         USAGE: flare <subcommand> [options]\n\
         \n\
         SUBCOMMANDS\n\
           info                        manifest + artifact summary\n\
           gen-data --dataset <name>   run a simulator, print statistics\n\
                    [--count K] [--stats]\n\
           train    [--case <name>]    train end-to-end (any backend;\n\
                    default case core_darcy_flare)\n\
                    [--steps N] [--eval-every K] [--ckpt FILE] [--quiet]\n\
                    [--resume FILE]    continue from a --ckpt checkpoint\n\
                    [--accum K]        sum gradients over K micro-batches\n\
                                       per optimizer step (native backend)\n\
                    [--ckpt-every K]   also write --ckpt every K steps\n\
           serve    --case <name>      serving engine + demo load\n\
                    [--requests K] [--concurrency C]\n\
           serve-bench                 closed-loop serving load generator:\n\
                    [--case <name>] [--requests K] [--concurrency C]\n\
                    [--max-wait-ms W] [--quiet] [--quick]\n\
                                       p50/p99 latency + req/s, dumped into\n\
                                       results/serve_bench.json for\n\
                                       bench-report ($FLARE_BENCH_QUICK=1\n\
                                       matches --quick)\n\
           spectra  --case <name>      eigenanalysis (paper Algorithm 1)\n\
                    [--steps N]\n\
           bench-report               fold results/*.json benchmark dumps\n\
                    [--results DIR] [--out FILE]   into BENCH_native.json\n\
                    [--compare BASELINE.json [--max-regression R]]\n\
                                       exit non-zero when any shared op's\n\
                                       median ns/op regresses past R (1.5)\n\
         \n\
         GLOBAL: --artifacts <dir>     artifacts directory (missing manifest\n\
                                       falls back to builtin native cases)\n\
                 --backend <name>      native | xla ($FLARE_BACKEND)\n"
    );
}

fn cmd_info(args: &Args) -> anyhow::Result<()> {
    let m = Manifest::load_or_builtin(manifest_dir(args))?;
    println!("artifacts dir : {:?}", m.dir);
    println!("seed          : {}", m.seed);
    println!("cases         : {}", m.cases.len());
    println!("mixer artifacts: {}", m.mixers.len());
    println!("layer artifacts: {}", m.layers.len());
    let mut groups: std::collections::BTreeMap<&str, usize> = Default::default();
    for c in &m.cases {
        *groups.entry(c.group.as_str()).or_default() += 1;
    }
    for (g, n) in groups {
        println!("  group {g:<8} {n} cases");
    }
    Ok(())
}

fn cmd_gen_data(args: &Args) -> anyhow::Result<()> {
    let m = Manifest::load_or_builtin(manifest_dir(args))?;
    let name = args.get_or("dataset", "darcy").to_string();
    let count = args.get_usize("count")?.unwrap_or(4);
    // find a case that uses this dataset to get its metadata
    let case = m
        .cases
        .iter()
        .find(|c| c.dataset == name)
        .ok_or_else(|| anyhow::anyhow!("no case uses dataset {name:?}"))?;
    let mut meta = case.dataset_meta.clone();
    if let flare::util::json::Json::Obj(ref mut o) = meta {
        o.insert("train".into(), flare::util::json::Json::num(count as f64));
        o.insert("test".into(), flare::util::json::Json::num(1.0));
    }
    let t = Timer::start();
    let ds = data::build(&name, &meta, m.seed)?;
    println!(
        "generated {} train + {} test samples of {:?} in {:.2}s",
        ds.train_len(),
        ds.test_len(),
        name,
        t.elapsed_s()
    );
    if ds.is_classification() {
        let mut counts = std::collections::BTreeMap::new();
        for s in &ds.train_tokens {
            *counts.entry(s.label).or_insert(0usize) += 1;
        }
        println!("n = {} tokens/sample, label histogram: {counts:?}", ds.n);
    } else {
        println!("n = {} points, d_in = {}, d_out = {}", ds.n, ds.d_in, ds.d_out);
        let ys: Vec<f64> = ds
            .train_fields
            .iter()
            .flat_map(|s| s.y.iter().map(|&v| v as f64))
            .collect();
        let stats = flare::util::stats::Summary::of(&ys);
        println!(
            "target field: mean {:.4} std {:.4} min {:.4} max {:.4}",
            stats.mean, stats.std, stats.min, stats.max
        );
    }
    if args.has_flag("stats") && name == "lpbf" {
        // Table-6-style part statistics
        println!("\nLPBF part statistics (Table 6 analogue, 10 parts):");
        let mut rng = flare::util::rng::Rng::new(m.seed);
        println!(
            "{:>8} {:>8} {:>12} {:>14}",
            "points", "edges", "height(mm)", "max |disp|"
        );
        for _ in 0..10 {
            let st = data::lpbf::stats(&mut rng, 4096);
            println!(
                "{:>8} {:>8} {:>12.1} {:>14.4}",
                st.points, st.edges, st.max_height_mm, st.max_displacement
            );
        }
    }
    Ok(())
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let m = Manifest::load_or_builtin(manifest_dir(args))?;
    let name = args.get_or("case", "core_darcy_flare").to_string();
    let case = m.case(&name)?;
    let backend = backend_from_args(args)?;
    let resume = match args.get("resume") {
        Some(path) => {
            let ck = flare::model::load_checkpoint(path)?;
            anyhow::ensure!(
                ck.case == name,
                "checkpoint {path:?} was written for case {:?}, not {name:?}",
                ck.case
            );
            let len = ck.params.len();
            // legacy params-only checkpoints (empty moments) resume with
            // zeros; any other length is corruption, not legacy
            anyhow::ensure!(
                (ck.m.len() == len && ck.v.len() == len) || (ck.m.is_empty() && ck.v.is_empty()),
                "checkpoint {path:?} moment lengths {}/{} do not match {len} params",
                ck.m.len(),
                ck.v.len()
            );
            let mom = if ck.m.is_empty() { vec![0.0; len] } else { ck.m };
            let vel = if ck.v.is_empty() { vec![0.0; len] } else { ck.v };
            println!("resuming from {path} at step {}", ck.step);
            Some((
                flare::runtime::OptState {
                    params: ck.params,
                    m: mom,
                    v: vel,
                },
                ck.step,
            ))
        }
        None => None,
    };
    let accum = args.get_usize("accum")?.unwrap_or(1).max(1);
    let ckpt_every = args.get_usize("ckpt-every")?.unwrap_or(0);
    anyhow::ensure!(
        ckpt_every == 0 || args.get("ckpt").is_some(),
        "--ckpt-every needs --ckpt FILE to know where to write"
    );
    let opts = TrainOpts {
        steps: args.get_usize("steps")?,
        eval_every: args.get_usize("eval-every")?.unwrap_or(0),
        sample_seed: 0x5EED,
        log_every: if args.has_flag("quiet") { 0 } else { 25 },
        resume,
        accum,
        ckpt_every,
        ckpt_path: args.get("ckpt").map(std::path::PathBuf::from),
    };
    println!(
        "training {name} on {} backend: {} params, dataset {}, batch {}{}",
        backend.name(),
        case.param_count,
        case.dataset,
        case.batch,
        if accum > 1 {
            format!(" (x{accum} accumulated = {} effective)", accum * case.batch)
        } else {
            String::new()
        }
    );
    let out = train_case(backend.as_ref(), &m, case, &opts)?;
    println!(
        "done: {} steps in {:.1}s ({:.1} ms/step p50 {:.1})",
        out.steps, out.wall_s, out.step_ms.mean, out.step_ms.p50
    );
    println!(
        "first/last loss: {:.4} -> {:.4}; final test metric: {:.5}",
        out.losses.first().copied().unwrap_or(f64::NAN),
        out.losses.last().copied().unwrap_or(f64::NAN),
        out.final_metric
    );
    if let Some(path) = args.get("ckpt") {
        flare::model::save_checkpoint(
            path,
            &flare::model::Checkpoint {
                case: out.case.clone(),
                step: out.steps,
                params: out.params.clone(),
                m: out.opt_m.clone(),
                v: out.opt_v.clone(),
                train_loss: out.losses.last().copied().unwrap_or(0.0),
            },
        )?;
        println!("checkpoint written to {path} (full optimizer state; resume with --resume)");
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let dir = manifest_dir(args);
    let m = Manifest::load_or_builtin(&dir)?;
    let name = args.get_or("case", "core_darcy_flare").to_string();
    let case = m.case(&name)?.clone();
    let requests = args.get_usize("requests")?.unwrap_or(16);
    let concurrency = args.get_usize("concurrency")?.unwrap_or(4).max(1);

    println!(
        "starting server for {name} (n={}, batch={})",
        case.model.n, case.batch
    );
    let server = Server::start(
        dir,
        ServerConfig {
            cases: vec![name.clone()],
            max_wait: std::time::Duration::from_millis(10),
            params: vec![],
            backend: args.get("backend").map(str::to_string),
        },
    )?;
    let ds = data::build(&case.dataset, &case.dataset_meta, m.seed)?;
    let t = Timer::start();
    std::thread::scope(|scope| {
        for w in 0..concurrency {
            let server = &server;
            let ds = &ds;
            let case = &case;
            scope.spawn(move || {
                for i in 0..requests / concurrency {
                    let s = &ds.test_fields[(w + i) % ds.test_len()];
                    let resp = server.infer(s.x.clone(), case.model.n).expect("infer");
                    assert_eq!(resp.y.len(), case.model.n * case.model.d_out);
                }
            });
        }
    });
    let wall = t.elapsed_s();
    let served = (requests / concurrency) * concurrency;
    println!(
        "served {served} requests in {wall:.2}s ({:.1} req/s)",
        served as f64 / wall
    );
    println!("{}", server.metrics.report());
    server.shutdown()?;
    Ok(())
}

/// Closed-loop serving load generator: `--concurrency` client threads each
/// issue blocking `infer` calls back to back against the serving engine and
/// record end-to-end latency.  Reports p50/p99 latency and req/s, and dumps
/// a bench measurement into `results/serve_bench.json` so `bench-report`
/// folds serving into `BENCH_native.json` (and the CI perf gate covers it
/// via the `serve_bench` entries in `BENCH_baseline.json`).
fn cmd_serve_bench(args: &Args) -> anyhow::Result<()> {
    use std::sync::Mutex;
    let dir = manifest_dir(args);
    let m = Manifest::load_or_builtin(&dir)?;
    let name = args.get_or("case", "core_darcy_flare").to_string();
    let case = m.case(&name)?.clone();
    let quick = args.has_flag("quick") || flare::bench::quick_mode();
    let concurrency = args.get_usize("concurrency")?.unwrap_or(4).max(1);
    let requests = args
        .get_usize("requests")?
        .unwrap_or(if quick { 16 } else { 64 })
        .max(concurrency);
    let max_wait = args.get_usize("max-wait-ms")?.unwrap_or(5);
    // spread the load exactly: the first `requests % concurrency` clients
    // issue one extra request, so nothing is silently dropped to rounding
    let base = requests / concurrency;
    let extra = requests % concurrency;

    println!(
        "serve-bench: {name} (n={}, batch={}), {concurrency} clients, {requests} requests, \
         max_wait {max_wait}ms",
        case.model.n, case.batch
    );
    let server = Server::start(
        dir,
        ServerConfig {
            cases: vec![name.clone()],
            max_wait: std::time::Duration::from_millis(max_wait as u64),
            params: vec![],
            backend: args.get("backend").map(str::to_string),
        },
    )?;

    let x = vec![0.25f32; case.model.n * case.model.d_in];
    // warmup: fill the per-bucket workspaces and the worker-local pools so
    // the timed window measures the steady state
    for _ in 0..2usize.max(case.batch) {
        server.infer(x.clone(), case.model.n)?;
    }

    let latencies_ms: Mutex<Vec<f64>> = Mutex::new(Vec::with_capacity(requests));
    let wall = Timer::start();
    std::thread::scope(|scope| {
        for cidx in 0..concurrency {
            let server = &server;
            let x = &x;
            let latencies_ms = &latencies_ms;
            let n = case.model.n;
            let my_requests = base + usize::from(cidx < extra);
            scope.spawn(move || {
                let mut local = Vec::with_capacity(my_requests);
                for _ in 0..my_requests {
                    let t = Timer::start();
                    let resp = server.infer(x.clone(), n).expect("infer");
                    assert_eq!(resp.y.len(), n * case.model.d_out);
                    local.push(t.elapsed_ms());
                }
                latencies_ms.lock().unwrap().extend_from_slice(&local);
            });
        }
    });
    let wall_s = wall.elapsed_s();
    let latencies = latencies_ms.into_inner().unwrap();
    let served = latencies.len();
    let summary = flare::util::stats::Summary::of(&latencies);
    let req_per_s = served as f64 / wall_s;
    println!(
        "served {served} requests in {wall_s:.2}s: {req_per_s:.1} req/s, \
         p50 {:.2} ms, p95 {:.2} ms, p99 {:.2} ms",
        summary.p50, summary.p95, summary.p99
    );
    if !args.has_flag("quiet") {
        println!("{}", server.metrics.report());
    }
    server.shutdown()?;

    let measurement = flare::bench::Measurement {
        name: format!("serve_closed_loop_c{concurrency}"),
        iters: served,
        total_s: wall_s,
        per_iter: summary.clone(),
        extras: vec![
            ("req_per_s".into(), req_per_s),
            ("p99_ms".into(), summary.p99),
            ("clients".into(), concurrency as f64),
            ("max_wait_ms".into(), max_wait as f64),
        ],
    };
    let path = flare::bench::save_results("serve_bench", &[measurement])?;
    println!("results written to {path:?}");
    Ok(())
}

/// Fold the `results/*.json` dumps written by the benches into one
/// `BENCH_native.json` perf artifact: per-op median ns, worker threads and
/// the git sha, validated after writing so CI fails on malformed output.
fn cmd_bench_report(args: &Args) -> anyhow::Result<()> {
    use flare::util::json::{parse, Json};
    // default: $FLARE_RESULTS (what save_results honors), else the union of
    // ./results and rust/results — cargo run keeps the invoker's cwd while
    // cargo bench runs the dump-writing binaries from the package root, so
    // dumps can legitimately sit in either
    let dirs: Vec<std::path::PathBuf> = match args.get("results") {
        Some(d) => vec![std::path::PathBuf::from(d)],
        None => match std::env::var("FLARE_RESULTS") {
            Ok(v) => vec![std::path::PathBuf::from(v)],
            Err(_) => vec!["results".into(), "rust/results".into()],
        },
    };
    let out_path = std::path::PathBuf::from(args.get_or("out", "BENCH_native.json"));
    let mut files: Vec<std::path::PathBuf> = Vec::new();
    for dir in &dirs {
        if let Ok(rd) = std::fs::read_dir(dir) {
            files.extend(
                rd.filter_map(|e| e.ok().map(|e| e.path()))
                    .filter(|p| p.extension().map(|x| x == "json").unwrap_or(false)),
            );
        }
    }
    files.sort();
    anyhow::ensure!(!files.is_empty(), "no *.json bench dumps in {dirs:?}");
    let mut ops: Vec<Json> = Vec::new();
    // (bench, name, median_ns) kept flat for the --compare perf gate
    let mut measured: Vec<(String, String, f64)> = Vec::new();
    for path in &files {
        let text = std::fs::read_to_string(path)?;
        let parsed =
            parse(&text).map_err(|e| anyhow::anyhow!("malformed bench dump {path:?}: {e}"))?;
        let Some(arr) = parsed.as_arr() else {
            // results/ also collects non-bench dumps (e.g. the train_darcy
            // example's e2e record); only measurement arrays are folded
            eprintln!("skipping {path:?}: not a bench measurement array");
            continue;
        };
        let bench = path
            .file_stem()
            .and_then(|s| s.to_str())
            .unwrap_or("bench")
            .to_string();
        for m in arr {
            let name = m
                .get("name")
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("measurement without name in {path:?}"))?;
            let p50 = m.get("p50_ms").as_f64().ok_or_else(|| {
                anyhow::anyhow!("measurement {name:?} without p50_ms in {path:?}")
            })?;
            anyhow::ensure!(
                p50.is_finite() && p50 >= 0.0,
                "measurement {name:?} has invalid p50_ms {p50}"
            );
            let iters = m.get("iters").as_f64().unwrap_or(0.0);
            measured.push((bench.clone(), name.to_string(), p50 * 1e6));
            ops.push(Json::obj(vec![
                ("bench", Json::str(&bench)),
                ("name", Json::str(name)),
                ("median_ns", Json::num(p50 * 1e6)),
                ("iters", Json::num(iters)),
            ]));
        }
    }
    anyhow::ensure!(!ops.is_empty(), "bench dumps contained no measurements");
    let threads = flare::runtime::NativeBackend::new().threads();
    let sha = std::env::var("GITHUB_SHA")
        .ok()
        .filter(|s| !s.is_empty())
        .or_else(git_head_sha)
        .unwrap_or_else(|| "unknown".to_string());
    let count = ops.len();
    let report = Json::obj(vec![
        ("schema", Json::num(1.0)),
        ("backend", Json::str("native")),
        ("git_sha", Json::str(&sha)),
        ("threads", Json::num(threads as f64)),
        ("ops", Json::Arr(ops)),
    ]);
    std::fs::write(&out_path, report.to_string())?;
    // self-check: the artifact must re-parse with a non-empty ops list
    let back = parse(&std::fs::read_to_string(&out_path)?)?;
    let n = back.get("ops").as_arr().map(|a| a.len()).unwrap_or(0);
    anyhow::ensure!(n == count, "written {out_path:?} failed validation");
    println!("wrote {out_path:?}: {n} ops, {threads} threads, sha {sha}");

    // perf-regression gate: compare every shared (bench, name) against the
    // committed baseline and fail when the median regresses past the bound
    if let Some(base_path) = args.get("compare") {
        let max_reg = args.get_f64("max-regression")?.unwrap_or(1.5);
        anyhow::ensure!(max_reg > 0.0, "--max-regression must be positive");
        let base = parse(&std::fs::read_to_string(base_path)?)
            .map_err(|e| anyhow::anyhow!("malformed baseline {base_path:?}: {e}"))?;
        let mut baseline: std::collections::BTreeMap<(String, String), f64> = Default::default();
        if let Some(arr) = base.get("ops").as_arr() {
            for op in arr {
                if let (Some(b), Some(nm), Some(med)) = (
                    op.get("bench").as_str(),
                    op.get("name").as_str(),
                    op.get("median_ns").as_f64(),
                ) {
                    baseline.insert((b.to_string(), nm.to_string()), med);
                }
            }
        }
        let mut compared = 0usize;
        let mut regressions: Vec<String> = Vec::new();
        for (bench, op_name, median_ns) in &measured {
            let Some(&base_ns) = baseline.get(&(bench.clone(), op_name.clone())) else {
                continue;
            };
            if base_ns <= 0.0 {
                continue;
            }
            compared += 1;
            let ratio = median_ns / base_ns;
            if ratio > max_reg {
                regressions.push(format!(
                    "{bench}/{op_name}: {median_ns:.0} ns vs baseline {base_ns:.0} ns \
                     ({ratio:.2}x > {max_reg:.2}x)"
                ));
            }
        }
        anyhow::ensure!(
            compared > 0,
            "perf gate compared 0 ops against {base_path:?} — baseline and run share no \
             benchmark names; refresh the baseline (see README)"
        );
        if regressions.is_empty() {
            println!("perf gate: {compared} shared ops within {max_reg:.2}x of {base_path:?}");
        } else {
            for r in &regressions {
                eprintln!("REGRESSION {r}");
            }
            anyhow::bail!(
                "{} of {compared} benchmark(s) regressed more than {max_reg}x vs {base_path:?}.\n\
                 If this change is a deliberate perf trade (or the baseline is stale), refresh \
                 the baseline: download the BENCH_native artifact from a green bench-smoke run \
                 on main — or regenerate locally on comparable hardware with\n\
                 \x20 FLARE_BENCH_QUICK=1 cargo bench -p flare --bench fig2_scaling\n\
                 \x20 FLARE_BENCH_QUICK=1 cargo bench -p flare --bench train_step\n\
                 \x20 cargo run -p flare --release -- bench-report --results rust/results \
                 --out BENCH_native.json\n\
                 — and commit the result as BENCH_baseline.json (see README \"Performance\").",
                regressions.len()
            );
        }
    }
    Ok(())
}

fn git_head_sha() -> Option<String> {
    let out = std::process::Command::new("git")
        .args(["rev-parse", "HEAD"])
        .output()
        .ok()?;
    if !out.status.success() {
        return None;
    }
    let sha = String::from_utf8(out.stdout).ok()?.trim().to_string();
    if sha.is_empty() {
        None
    } else {
        Some(sha)
    }
}

fn cmd_spectra(args: &Args) -> anyhow::Result<()> {
    let m = Manifest::load_or_builtin(manifest_dir(args))?;
    let name = args.get_or("case", "core_elas_flare").to_string();
    let case = m.case(&name)?;
    let backend = backend_from_args(args)?;

    // optionally train first so the spectra reflect learned routing
    let steps = args.get_usize("steps")?.unwrap_or(100);
    let params_host = if steps > 0 && backend.supports_training() {
        println!("training {steps} steps first...");
        let out = train_case(
            backend.as_ref(),
            &m,
            case,
            &TrainOpts {
                steps: Some(steps),
                ..Default::default()
            },
        )?;
        println!("trained to rel-L2 {:.4}", out.final_metric);
        out.params
    } else {
        if steps > 0 {
            println!(
                "backend {:?} cannot train; analyzing the seeded init instead",
                backend.name()
            );
        }
        init_params(&case.params, case.param_count, m.seed)
    };

    // evaluate per-block keys at a test sample through the backend
    let ds = data::build(&case.dataset, &case.dataset_meta, m.seed)?;
    let sample = &ds.test_fields[0];
    let ks = backend.qk_keys(&m, case, &params_host, &sample.x)?;

    let (h, mm, d, n) = (
        case.model.heads,
        case.model.m,
        case.model.head_dim(),
        case.model.n,
    );
    println!(
        "\nSpectra (paper Fig. 12): blocks={} heads={h} M={mm} D={d} N={n}",
        case.model.blocks
    );
    for (b, kvals) in ks.iter().enumerate() {
        // kvals: [H, N, D]
        let latents = find_entry(&case.params, &format!("blk{b}.mix.latents"))?;
        let q_all = param_slice(&params_host, latents); // [H, M, D] or [M, D]
        let mut spectra = Vec::new();
        for head in 0..h {
            let q = if case.model.shared_latents {
                q_all.to_vec()
            } else {
                q_all[head * mm * d..(head + 1) * mm * d].to_vec()
            };
            let k = &kvals[head * n * d..(head + 1) * n * d];
            let eig = eig_lowrank(&q, k, mm, n, d);
            let sp = HeadSpectrum {
                block: b,
                head,
                eigenvalues: eig.eigenvalues,
            };
            let top: Vec<String> = sp.eigenvalues[..4.min(mm)]
                .iter()
                .map(|l| format!("{l:.3}"))
                .collect();
            println!(
                "  block {b} head {head}: top l [{}] eff-rank {} entropy {:.3}",
                top.join(", "),
                sp.effective_rank(1e-3),
                sp.spectral_entropy()
            );
            spectra.push(sp);
        }
        println!(
            "  block {b} spectral diversity across heads: {:.4}",
            spectra_diversity(&spectra)
        );
    }
    Ok(())
}
