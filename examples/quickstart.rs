//! Quickstart: the minimal end-to-end path through the public API.
//!
//! Loads the artifact manifest (or the builtin artifact-free cases),
//! generates a small Darcy-flow dataset with the built-in simulator, trains
//! the FLARE surrogate for a handful of steps — native reverse-mode
//! gradients by default, the XLA step artifact behind `--features xla` —
//! and runs one prediction, all from Rust with Python nowhere on the path.
//!
//! Run with:  cargo run --release --example quickstart

use flare::config::Manifest;
use flare::data;
use flare::metrics::rel_l2;
use flare::runtime::{default_backend, BatchInput};
use flare::train::{train_case, TrainOpts};

fn main() -> anyhow::Result<()> {
    // 1. manifest: AOT-lowered models + packing specs when artifacts
    //    exist, the builtin native cases otherwise
    let manifest = Manifest::load_or_builtin(Manifest::default_dir())?;
    let case = manifest.case("core_darcy_flare")?;
    println!(
        "case {}: {} FLARE blocks, M={} latents/head, {} params",
        case.name, case.model.blocks, case.model.m, case.param_count
    );

    // 2. backend + training (one fused optimizer step per train_step)
    let backend = default_backend()?;
    let out = train_case(
        backend.as_ref(),
        &manifest,
        case,
        &TrainOpts {
            steps: Some(60),
            log_every: 20,
            ..Default::default()
        },
    )?;
    println!(
        "trained 60 steps in {:.1}s; loss {:.3} -> {:.3}; test rel-L2 {:.4}",
        out.wall_s,
        out.losses.first().unwrap(),
        out.losses.last().unwrap(),
        out.final_metric
    );

    // 3. one-off prediction with the trained parameters
    let ds = data::build(&case.dataset, &case.dataset_meta, manifest.seed)?;
    let sample = &ds.test_fields[0];
    let mut xb = sample.x.clone();
    xb.resize(case.batch * case.model.n * case.model.d_in, 0.0);
    let pred = backend.forward(case, &out.params, BatchInput::Fields(&xb), case.batch)?;
    let err = rel_l2(&pred[..sample.y.len()], &sample.y);
    println!("single-sample prediction rel-L2: {err:.4}");
    Ok(())
}
