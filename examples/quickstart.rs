//! Quickstart: the minimal end-to-end path through the public API.
//!
//! Loads the artifact manifest, generates a small Darcy-flow dataset with
//! the built-in simulator, trains the FLARE surrogate for a handful of
//! steps, and runs one prediction — all from Rust, with Python nowhere on
//! the hot path.
//!
//! Run with:  cargo run --release --example quickstart

use flare::config::Manifest;
use flare::data;
use flare::metrics::rel_l2;
use flare::runtime::literal::{lit_f32, to_vec_f32};
use flare::runtime::Runtime;
use flare::train::{train_case, TrainOpts};

fn main() -> anyhow::Result<()> {
    // 1. manifest: every AOT-lowered model + its parameter packing spec
    let manifest = Manifest::load(Manifest::default_dir())?;
    let case = manifest.case("core_darcy_flare")?;
    println!(
        "case {}: {} FLARE blocks, M={} latents/head, {} params",
        case.name, case.model.blocks, case.model.m, case.param_count
    );

    // 2. PJRT CPU runtime + training (one XLA execution per optimizer step)
    let rt = Runtime::cpu()?;
    let out = train_case(
        &rt,
        &manifest,
        case,
        &TrainOpts {
            steps: Some(60),
            log_every: 20,
            ..Default::default()
        },
    )?;
    println!(
        "trained 60 steps in {:.1}s; loss {:.3} -> {:.3}; test rel-L2 {:.4}",
        out.wall_s,
        out.losses.first().unwrap(),
        out.losses.last().unwrap(),
        out.final_metric
    );

    // 3. one-off prediction with the trained parameters
    let ds = data::build(&case.dataset, &case.dataset_meta, manifest.seed)?;
    let sample = &ds.test_fields[0];
    let fwd = rt.load("fwd", manifest.artifact_path(case, "fwd")?)?;
    let mut xb = sample.x.clone();
    xb.resize(case.batch * case.model.n * case.model.d_in, 0.0);
    let outs = rt.run(
        &fwd,
        &[
            lit_f32(&out.params, &[case.param_count as i64])?,
            lit_f32(
                &xb,
                &[
                    case.batch as i64,
                    case.model.n as i64,
                    case.model.d_in as i64,
                ],
            )?,
        ],
    )?;
    let pred = to_vec_f32(&outs[0])?;
    let err = rel_l2(&pred[..sample.y.len()], &sample.y);
    println!("single-sample prediction rel-L2: {err:.4}");
    Ok(())
}
