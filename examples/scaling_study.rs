//! Scaling study (paper Figure 5, CPU-scaled): sweep FLARE depth B and
//! latent count M on the large-N DrivAer-like dataset, reporting test
//! rel-L2, time per step and peak memory — the same three axes the paper
//! plots for its million-point study.
//!
//! Run with:  cargo run --release --example scaling_study [steps]

use flare::config::Manifest;
use flare::runtime::default_backend;
use flare::train::{train_case, TrainOpts};
use flare::util::stats::peak_rss_bytes;

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(20);
    let manifest = Manifest::load(Manifest::default_dir())?;
    let cases: Vec<_> = manifest.cases_in_group("fig5");
    anyhow::ensure!(!cases.is_empty(), "fig5 artifacts missing");

    println!(
        "Figure-5-style sweep on {} points/geometry ({} steps each):\n",
        cases[0].model.n, steps
    );
    println!(
        "{:<14} {:>3} {:>5} {:>10} {:>12} {:>12}",
        "case", "B", "M", "rel-L2", "ms/step", "peak RSS MB"
    );
    for case in cases {
        let backend = default_backend()?;
        let out = train_case(
            backend.as_ref(),
            &manifest,
            case,
            &TrainOpts {
                steps: Some(steps),
                ..Default::default()
            },
        )?;
        let rss = peak_rss_bytes().unwrap_or(0) as f64 / 1e6;
        println!(
            "{:<14} {:>3} {:>5} {:>10.4} {:>12.1} {:>12.0}",
            case.name, case.model.blocks, case.model.m, out.final_metric,
            out.step_ms.mean, rss
        );
    }
    println!(
        "\nexpected trends (paper Fig. 5): error falls with B; time grows \
         with B and M; memory stays nearly flat in M (O(NM) compute but \
         activations dominated by N)."
    );
    Ok(())
}
