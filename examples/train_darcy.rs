//! End-to-end training driver (the EXPERIMENTS.md §E2E run).
//!
//! Trains the FLARE Darcy surrogate for several hundred optimizer steps on
//! simulator-generated data, logging the loss curve, periodic test rel-L2,
//! step-time statistics, and writing the curve to `results/e2e_darcy.json`
//! plus a checkpoint — the full lifecycle a downstream user would run.
//! Runs on the default (native) backend with no artifacts anywhere: the
//! gradients come from the pure-Rust reverse pass in `model::backward`.
//!
//! Run with:  cargo run --release --example train_darcy [steps]

use flare::config::Manifest;
use flare::model::{save_checkpoint, Checkpoint};
use flare::runtime::default_backend;
use flare::train::{train_case, TrainOpts};
use flare::util::json::Json;

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);
    let manifest = Manifest::load_or_builtin(Manifest::default_dir())?;
    let case = manifest.case("core_darcy_flare")?;
    let backend = default_backend()?;

    println!("=== FLARE end-to-end training: Darcy flow surrogate ===");
    println!(
        "model: mixer={} C={} H={} M={} B={} | params {} | N={} batch={}",
        case.model.mixer,
        case.model.c,
        case.model.heads,
        case.model.m,
        case.model.blocks,
        case.param_count,
        case.model.n,
        case.batch
    );
    println!(
        "data: {} train / {} test simulator-generated Darcy solves",
        case.dataset_meta.get("train").as_usize().unwrap_or(0),
        case.dataset_meta.get("test").as_usize().unwrap_or(0)
    );

    let out = train_case(
        backend.as_ref(),
        &manifest,
        case,
        &TrainOpts {
            steps: Some(steps),
            eval_every: (steps / 6).max(1),
            log_every: (steps / 12).max(1),
            ..Default::default()
        },
    )?;

    println!("\nloss curve (every {} steps):", (steps / 15).max(1));
    for (i, loss) in out.losses.iter().enumerate() {
        if i % (steps / 15).max(1) == 0 || i + 1 == out.losses.len() {
            println!("  step {i:>5}  loss {loss:.4}");
        }
    }
    println!("\neval history (test rel-L2):");
    for (step, metric) in &out.evals {
        println!("  step {step:>5}  rel-L2 {metric:.4}");
    }
    println!(
        "\ntotals: {:.1}s wall, {:.1} ms/step (p50 {:.1}, p95 {:.1})",
        out.wall_s, out.step_ms.mean, out.step_ms.p50, out.step_ms.p95
    );
    println!("final test rel-L2: {:.4}", out.final_metric);

    // persist results + checkpoint
    let results_dir = std::path::PathBuf::from("results");
    std::fs::create_dir_all(&results_dir)?;
    let record = Json::obj(vec![
        ("case", Json::str(&out.case)),
        ("steps", Json::num(out.steps as f64)),
        ("losses", Json::arr_f64(&out.losses)),
        (
            "evals",
            Json::Arr(
                out.evals
                    .iter()
                    .map(|(s, m)| Json::arr_f64(&[*s as f64, *m]))
                    .collect(),
            ),
        ),
        ("final_rel_l2", Json::num(out.final_metric)),
        ("wall_s", Json::num(out.wall_s)),
        ("step_ms_mean", Json::num(out.step_ms.mean)),
    ]);
    std::fs::write(results_dir.join("e2e_darcy.json"), record.to_string())?;
    save_checkpoint(
        results_dir.join("e2e_darcy.ckpt"),
        &Checkpoint {
            case: out.case.clone(),
            step: out.steps,
            params: out.params.clone(),
            m: out.opt_m.clone(),
            v: out.opt_v.clone(),
            train_loss: *out.losses.last().unwrap(),
        },
    )?;
    println!("\nwrote results/e2e_darcy.json and results/e2e_darcy.ckpt");
    anyhow::ensure!(
        out.losses.last().unwrap() < &(out.losses[0] * 0.5),
        "training failed to reduce loss by 2x"
    );
    Ok(())
}
