//! Serving example: train a surrogate, hand its weights to the coordinator,
//! and drive it with concurrent clients — the "deploy" half of the paper's
//! motivating use case (multi-query design optimization needs thousands of
//! cheap surrogate evaluations).
//!
//! Run with:  cargo run --release --example serve_surrogate

use std::time::Duration;

use flare::config::Manifest;
use flare::coordinator::{Server, ServerConfig};
use flare::data;
use flare::metrics::rel_l2;
use flare::runtime::default_backend;
use flare::train::{train_case, TrainOpts};
use flare::util::stats::Timer;

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load(Manifest::default_dir())?;
    let case = manifest.case("core_darcy_flare")?.clone();

    // 1. train briefly so the served model is meaningful
    println!("training surrogate (120 steps)...");
    let backend = default_backend()?;
    let trained = train_case(
        backend.as_ref(),
        &manifest,
        &case,
        &TrainOpts {
            steps: Some(120),
            ..Default::default()
        },
    )?;
    println!("trained to test rel-L2 {:.4}", trained.final_metric);
    drop(backend); // the server brings its own backend on its executor thread

    // 2. start the coordinator with the trained weights
    let server = Server::start(
        manifest.dir.clone(),
        ServerConfig {
            cases: vec![case.name.clone()],
            max_wait: Duration::from_millis(8),
            params: vec![(case.name.clone(), trained.params.clone())],
            backend: None,
            ..ServerConfig::default()
        },
    )?;

    // 3. concurrent clients issuing queries from the test split
    let ds = data::build(&case.dataset, &case.dataset_meta, manifest.seed)?;
    let clients = 4;
    let per_client = 8;
    let t = Timer::start();
    let errs: Vec<f64> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                let server = &server;
                let ds = &ds;
                let case = &case;
                scope.spawn(move || {
                    let mut errs = Vec::new();
                    for i in 0..per_client {
                        let s = &ds.test_fields[(c * per_client + i) % ds.test_len()];
                        let resp = server.infer(s.x.clone(), case.model.n).expect("infer");
                        errs.push(rel_l2(&resp.y, &s.y));
                    }
                    errs
                })
            })
            .collect();
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
    });
    let wall = t.elapsed_s();

    let total = clients * per_client;
    let mean_err = errs.iter().sum::<f64>() / errs.len() as f64;
    println!(
        "\nserved {total} requests from {clients} clients in {wall:.2}s \
         ({:.1} req/s)",
        total as f64 / wall
    );
    println!("mean served rel-L2 vs simulator ground truth: {mean_err:.4}");
    println!("\ncoordinator metrics:\n{}", server.metrics.report());
    server.shutdown()?;
    Ok(())
}
