//! Spectral analysis example (paper Section 3.3 / Figure 12): train FLARE
//! on the elasticity benchmark (XLA backend; falls back to the seeded init
//! on backends that cannot train), then eigendecompose every head's induced
//! mixing operator W_h with Algorithm 1 and print the decay profiles,
//! effective ranks, and the cross-head diversity statistic.
//!
//! Run with:  cargo run --release --example spectral_analysis [steps]

use flare::config::Manifest;
use flare::data;
use flare::model::{find_entry, init_params, param_slice};
use flare::runtime::default_backend;
use flare::spectral::{eig_lowrank, spectra_diversity, HeadSpectrum};
use flare::train::{train_case, TrainOpts};

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(200);
    let manifest = Manifest::load(Manifest::default_dir())?;
    let case = manifest.case("core_elas_flare")?;
    let backend = default_backend()?;

    let params = if backend.supports_training() && steps > 0 {
        println!("training FLARE on elasticity ({steps} steps)...");
        let out = train_case(
            backend.as_ref(),
            &manifest,
            case,
            &TrainOpts {
                steps: Some(steps),
                ..Default::default()
            },
        )?;
        println!("test rel-L2: {:.4}\n", out.final_metric);
        out.params
    } else {
        println!(
            "backend {:?} cannot train; analyzing the seeded init instead\n",
            backend.name()
        );
        init_params(&case.params, case.param_count, manifest.seed)
    };

    // per-block keys at a real test sample, via the backend
    let ds = data::build(&case.dataset, &case.dataset_meta, manifest.seed)?;
    let ks = backend.qk_keys(&manifest, case, &params, &ds.test_fields[0].x)?;

    let (h, m, d, n) = (
        case.model.heads,
        case.model.m,
        case.model.head_dim(),
        case.model.n,
    );
    println!("eigenvalue decay per head (normalized to lambda_1 = 1):");
    for (b, kvals) in ks.iter().enumerate() {
        let latents = find_entry(&case.params, &format!("blk{b}.mix.latents"))?;
        let q_all = param_slice(&params, latents);
        let mut spectra = Vec::new();
        for head in 0..h {
            let q = &q_all[head * m * d..(head + 1) * m * d];
            let k = &kvals[head * n * d..(head + 1) * n * d];
            let eig = eig_lowrank(q, k, m, n, d);
            spectra.push(HeadSpectrum {
                block: b,
                head,
                eigenvalues: eig.eigenvalues,
            });
        }
        for sp in &spectra {
            let l1 = sp.eigenvalues[0].max(1e-30);
            let curve: Vec<String> = [0, 1, 2, 4, 8, 16]
                .iter()
                .filter(|&&i| i < m)
                .map(|&i| format!("{:.3}", sp.eigenvalues[i] / l1))
                .collect();
            println!(
                "  block {} head {}: [{}]  eff-rank {:>2}  entropy {:.2}",
                sp.block,
                sp.head,
                curve.join(" "),
                sp.effective_rank(1e-3),
                sp.spectral_entropy()
            );
        }
        println!(
            "  block {b}: cross-head spectral diversity = {:.4} \
             (higher = more complementary low-rank pathways)\n",
            spectra_diversity(&spectra)
        );
    }
    Ok(())
}
