"""The artifact matrix: every (model, dataset, shape) this repo lowers.

This module is the single source of truth shared by ``aot.py`` (which lowers
the artifacts) and the Rust side (which reads the same information from
``artifacts/manifest.json``).  Each :class:`Case` names a model configuration
bound to a dataset shape and lists which artifact kinds to emit:

* ``step`` — fused AdamW train step (params,m,v,step,lr,x,y)->(p',m',v',loss)
* ``eval`` — scalar metric (params,x,y)->rel-L2 or accuracy
* ``fwd``  — batched forward (params,x)->y
* ``qk``   — per-block key extraction for spectral analysis (FLARE only)

CPU-budget note: the paper trains C=64..128, B=8, N up to 1e6 on an H100.
This reproduction keeps the same *architecture and ratios* but scales widths
and sequence lengths to a single CPU core; every deviation is recorded here
and surfaced in EXPERIMENTS.md next to the measured numbers.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

from .models import ModelCfg
from .train import OptCfg

SEED = 42

# Datasets: name -> (n, d_in, d_out, generator params for the Rust simulator)
DATASETS: Dict[str, dict] = {
    "elasticity": {"n": 972, "d_in": 2, "d_out": 1, "kind": "elasticity",
                   "train": 192, "test": 48},
    "darcy": {"n": 1024, "d_in": 3, "d_out": 1, "kind": "darcy", "grid": 32,
              "train": 192, "test": 48},
    "airfoil": {"n": 1024, "d_in": 2, "d_out": 1, "kind": "airfoil",
                "grid_i": 64, "grid_j": 16, "train": 192, "test": 48},
    "pipe": {"n": 1089, "d_in": 2, "d_out": 1, "kind": "pipe", "grid": 33,
             "train": 192, "test": 48},
    "drivaer": {"n": 2048, "d_in": 3, "d_out": 1, "kind": "drivaer",
                "train": 96, "test": 24},
    "lpbf": {"n": 2048, "d_in": 3, "d_out": 1, "kind": "lpbf",
             "train": 96, "test": 24},
    # Figure 5 "million-point" study, CPU-scaled
    "drivaer_xl": {"n": 16384, "d_in": 3, "d_out": 1, "kind": "drivaer",
                   "train": 16, "test": 4},
    # LRA-style sequence tasks (Table 2)
    "listops": {"n": 512, "kind": "listops", "vocab": 18, "classes": 10,
                "train": 512, "test": 128},
    "text": {"n": 1024, "kind": "text", "vocab": 64, "classes": 2,
             "train": 512, "test": 128},
    "retrieval": {"n": 1024, "kind": "retrieval", "vocab": 64, "classes": 2,
                  "train": 512, "test": 128},
    "image": {"n": 1024, "kind": "image", "vocab": 256, "classes": 10,
              "train": 512, "test": 128},
    "pathfinder": {"n": 1024, "kind": "pathfinder", "vocab": 4, "classes": 2,
                   "train": 512, "test": 128},
}

LRA_TASKS = ("listops", "text", "retrieval", "image", "pathfinder")
PDE_SETS = ("elasticity", "darcy", "airfoil", "pipe", "drivaer", "lpbf")

# Table 1 model set (paper: vanilla excluded from the large 3D cases)
TABLE1_MODELS = ("flare", "vanilla", "perceiver", "lno", "transolver", "gnot")
# Table 2 model set
TABLE2_MODELS = ("flare", "vanilla", "linatt", "linformer", "performer")


@dataclasses.dataclass(frozen=True)
class Case:
    name: str
    group: str
    dataset: str
    model: ModelCfg
    opt: OptCfg = OptCfg()
    batch: int = 2
    kinds: Tuple[str, ...] = ("step", "eval")
    #: suggested training budget for the Rust driver (steps, not epochs)
    train_steps: int = 300
    lr: float = 1e-3


def _pde_cfg(dataset: str, mixer: str, **kw) -> ModelCfg:
    ds = DATASETS[dataset]
    base = dict(mixer=mixer, n=ds["n"], d_in=ds["d_in"], d_out=ds["d_out"],
                c=32, heads=4, m=32, blocks=2)
    if mixer == "perceiver":
        # PerceiverIO-style: generous latent array, latent SA stack
        base.update(m=64, blocks=2)
    elif mixer == "lno":
        # LNO-style: fewer latent modes, deeper latent transformer
        base.update(m=48, blocks=3, ffn_layers=2)
    base.update(kw)
    return ModelCfg(**base)


def _lra_cfg(dataset: str, mixer: str, **kw) -> ModelCfg:
    ds = DATASETS[dataset]
    base = dict(mixer=mixer, n=ds["n"], d_in=0, d_out=0, c=32, heads=4,
                m=32, blocks=2, task="classification", vocab=ds["vocab"],
                num_classes=ds["classes"])
    base.update(kw)
    return ModelCfg(**base)


def build_cases() -> List[Case]:
    cases: List[Case] = []

    # ---- core: exercised by tests, examples and the serving engine -------
    cases.append(Case("core_darcy_flare", "core", "darcy",
                      _pde_cfg("darcy", "flare"),
                      kinds=("step", "eval", "fwd"), train_steps=300))
    cases.append(Case("core_elas_flare", "core", "elasticity",
                      _pde_cfg("elasticity", "flare"),
                      kinds=("step", "eval", "fwd", "qk"), train_steps=300))

    # ---- Table 1: PDE benchmarks across models ---------------------------
    for ds in PDE_SETS:
        for mixer in TABLE1_MODELS:
            if mixer == "vanilla" and ds in ("drivaer", "lpbf"):
                continue  # paper marks vanilla "~" (prohibitively slow)
            batch = 1 if ds in ("drivaer", "lpbf") else 2
            cases.append(Case(f"t1_{ds}_{mixer}", "table1", ds,
                              _pde_cfg(ds, mixer), batch=batch,
                              train_steps=300))

    # ---- Table 2: LRA tasks across attention variants --------------------
    for ds in LRA_TASKS:
        for mixer in TABLE2_MODELS:
            cases.append(Case(f"t2_{ds}_{mixer}", "table2", ds,
                              _lra_cfg(ds, mixer), batch=8, train_steps=400,
                              opt=OptCfg(weight_decay=1e-4)))

    # ---- Figure 5: large-N error/time/memory vs (B, M) -------------------
    for b in (1, 2, 4):
        for m in (32, 128):
            cases.append(Case(f"f5_b{b}_m{m}", "fig5", "drivaer_xl",
                              _pde_cfg("drivaer_xl", "flare", blocks=b, m=m,
                                       mixer_impl="chunked"),
                              batch=1, train_steps=60))

    # ---- Figure 9: error vs (B, M) on elasticity + darcy -----------------
    for ds in ("elasticity", "darcy"):
        for b in (1, 2, 4):
            for m in (8, 32, 64):
                cases.append(Case(f"f9_{ds}_b{b}_m{m}", "fig9", ds,
                                  _pde_cfg(ds, "flare", blocks=b, m=m),
                                  train_steps=250))

    # ---- Figure 10: ResMLP depth ablations on elasticity ------------------
    for kv in (0, 1, 3, 5):
        cases.append(Case(f"f10_kv{kv}", "fig10", "elasticity",
                          _pde_cfg("elasticity", "flare", kv_layers=kv),
                          train_steps=250))
    for ffn in (0, 1, 3, 5):
        cases.append(Case(f"f10_ffn{ffn}", "fig10", "elasticity",
                          _pde_cfg("elasticity", "flare", ffn_layers=ffn),
                          train_steps=250))

    # ---- Figure 11: latent-SA blocks (L_B) vs FLARE blocks (B) -----------
    for b in (1, 2, 4):
        for lb in (0, 2, 4):
            cases.append(Case(f"f11_b{b}_lb{lb}", "fig11", "elasticity",
                              _pde_cfg("elasticity", "flare", blocks=b,
                                       latent_sa_blocks=lb),
                              train_steps=250))

    # ---- Figure 12: shared vs independent latent slices ------------------
    for b in (2, 4):
        for shared in (False, True):
            tag = "shared" if shared else "indep"
            cases.append(Case(f"f12_b{b}_{tag}", "fig12", "elasticity",
                              _pde_cfg("elasticity", "flare", blocks=b,
                                       shared_latents=shared),
                              kinds=("step", "eval", "qk"), train_steps=250))

    # ---- Figure 13: head dimension sweep (C fixed) ------------------------
    for h in (1, 2, 4, 8):
        cases.append(Case(f"f13_h{h}", "fig13", "elasticity",
                          _pde_cfg("elasticity", "flare", heads=h),
                          train_steps=250))

    names = [c.name for c in cases]
    if len(names) != len(set(names)):
        raise AssertionError("duplicate case names")
    return cases


# ---------------------------------------------------------------------------
# Standalone mixer / bare-layer artifacts (Figures 2 and 8)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MixerArtifact:
    """Bare token-mixer forward at a given scale (Figure 2)."""

    name: str
    kind: str       #: flare_chunked | flare_pallas | flare_sdpa | vanilla_sdpa
    n: int
    m: int          #: latents per head (flare) / unused (vanilla)
    heads: int = 8
    head_dim: int = 8
    group: str = "fig2"


@dataclasses.dataclass(frozen=True)
class LayerArtifact:
    """Single bare mixing layer on [N, C] (Figure 8)."""

    name: str
    mixer: str
    n: int
    c: int = 32
    heads: int = 4
    m: int = 32
    group: str = "fig8"


def build_mixer_artifacts() -> List[MixerArtifact]:
    arts: List[MixerArtifact] = []
    # §Perf L2 (measured, see EXPERIMENTS.md §Perf): dense sdpa form wins
    # below the chunk size (6.0ms vs 19.7ms at N=1024/M=64 — the scan
    # machinery is pure overhead for a single chunk); the chunked streaming
    # form wins from N=4096 up (75ms vs 165ms at N=16384/M=64) and bounds
    # memory at the 1M-token headline point.
    for n in (1024, 4096, 16384, 65536, 262144):
        kind = "flare_sdpa" if n < 4096 else "flare_chunked"
        for m in (64, 256):
            arts.append(MixerArtifact(f"mx_flare_n{n}_m{m}", kind, n, m))
    # million-token headline point (flare only; vanilla cannot reach it)
    arts.append(MixerArtifact("mx_flare_n1048576_m64", "flare_chunked", 1048576, 64))
    for n in (512, 1024, 2048, 4096):
        arts.append(MixerArtifact(f"mx_vanilla_n{n}", "vanilla_sdpa", n, 0))
    # pallas-kernel round-trip proof at a moderate size
    arts.append(MixerArtifact("mx_pallas_n4096_m64", "flare_pallas", 4096, 64))
    arts.append(MixerArtifact("mx_sdpa_n1024_m64", "flare_sdpa", 1024, 64))
    return arts


def build_layer_artifacts() -> List[LayerArtifact]:
    arts: List[LayerArtifact] = []
    for n in (1024, 4096, 16384):
        for mixer in ("flare", "vanilla", "transolver"):
            if mixer == "vanilla" and n > 4096:
                continue
            arts.append(LayerArtifact(f"ly_{mixer}_n{n}", mixer, n))
    return arts


GROUPS = ("core", "table1", "table2", "fig2", "fig5", "fig8", "fig9",
          "fig10", "fig11", "fig12", "fig13")
