"""AOT lowering: JAX/Pallas -> HLO text artifacts + manifest.json.

Run once via ``make artifacts``; Rust loads the results through
``HloModuleProto::from_text_file`` (text, *not* serialized protos — the
image's xla_extension 0.5.1 rejects jax>=0.5 64-bit instruction ids; the
text parser reassigns ids and round-trips cleanly).

Usage:
    python -m compile.aot --out ../artifacts [--group core,table1,...]
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import cases as cases_mod
from . import models, train
from .cases import DATASETS, SEED, Case, LayerArtifact, MixerArtifact
from .kernels import flare_mixer as fm
from .models import ModelCfg


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _write(out_dir: str, name: str, text: str) -> str:
    fname = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    return fname


# ---------------------------------------------------------------------------
# Case lowering
# ---------------------------------------------------------------------------

def lower_case(case: Case, out_dir: str) -> dict:
    cfg = case.model
    spec = models.build_spec(cfg)
    p = spec.total
    ds = DATASETS[case.dataset]

    if cfg.task == "classification":
        x_sds = _sds((case.batch, cfg.n), jnp.int32)
        y_sds = _sds((case.batch,), jnp.int32)
    else:
        x_sds = _sds((case.batch, cfg.n, cfg.d_in))
        y_sds = _sds((case.batch, cfg.n, cfg.d_out))

    artifacts = {}
    for kind in case.kinds:
        if kind == "step":
            fn = train.make_train_step(cfg, spec, case.opt)
            args = (_sds((p,)), _sds((p,)), _sds((p,)), _sds(()), _sds(()),
                    x_sds, y_sds)
            # §Perf L2 note: buffer donation (donate_argnums=(0,1,2)) was
            # tried and REVERTED — with host-literal inputs on the CPU PJRT
            # path the measured step time was neutral-to-slightly-worse
            # (p50 73-78ms vs 65-73ms), because every step already pays the
            # host->device copy and aliasing adds no win.  See EXPERIMENTS.md
            # §Perf.
        elif kind == "eval":
            fn = train.make_eval_fn(cfg, spec)
            args = (_sds((p,)), x_sds, y_sds)
        elif kind == "fwd":
            fn = train.make_forward_fn(cfg, spec)
            args = (_sds((p,)), x_sds)
        elif kind == "qk":
            fn = lambda flat, x: models.qk_forward(cfg, spec, flat, x)
            args = (_sds((p,)), _sds((cfg.n, cfg.d_in)))
        else:
            raise ValueError(f"unknown artifact kind {kind}")
        lowered = jax.jit(fn).lower(*args)
        artifacts[kind] = _write(out_dir, f"{case.name}_{kind}", to_hlo_text(lowered))

    # golden outputs for Rust<->Python parity tests: run the forward pass on
    # a deterministic input with the seeded init and record a fingerprint
    if case.group == "core" and "fwd" in case.kinds and cfg.task == "regression":
        import numpy as np

        from . import rnginit

        params = jnp.asarray(spec.init_flat(SEED))
        count = case.batch * cfg.n * cfg.d_in
        xs = rnginit.u01(1234, np.arange(count, dtype=np.uint64)) * 2.0 - 1.0
        x = jnp.asarray(xs.reshape(case.batch, cfg.n, cfg.d_in), jnp.float32)
        y = np.asarray(train.make_forward_fn(cfg, spec)(params, x))
        golden = {
            "head": [float(v) for v in y.reshape(-1)[:16]],
            "l2": float(np.sqrt((y.astype(np.float64) ** 2).sum())),
        }
        with open(os.path.join(out_dir, f"{case.name}_golden.json"), "w") as f:
            json.dump(golden, f)

    entry = {
        "name": case.name,
        "group": case.group,
        "dataset": case.dataset,
        "dataset_meta": ds,
        "batch": case.batch,
        "train_steps": case.train_steps,
        "lr": case.lr,
        "model": dataclasses.asdict(cfg),
        "opt": dataclasses.asdict(case.opt),
        "param_count": p,
        "artifacts": artifacts,
        "params": spec.to_manifest(),
    }
    return entry


# ---------------------------------------------------------------------------
# Bare mixer artifacts (Figure 2)
# ---------------------------------------------------------------------------

def lower_mixer(art: MixerArtifact, out_dir: str) -> dict:
    h, d, n, m = art.heads, art.head_dim, art.n, art.m
    if art.kind == "vanilla_sdpa":
        def fn(q, k, v):
            s = jnp.einsum("hqd,hkd->hqk", q, k) / (d ** 0.5)
            return jnp.einsum("hqk,hkd->hqd", jax.nn.softmax(s, axis=-1), v)
        args = (_sds((h, n, d)),) * 3
    else:
        if art.kind == "flare_chunked":
            # §Perf: chunk=4096 measured best at N in [4k, 262k] (16384
            # chunks showed no gain and cost memory at 1M tokens)
            fn = lambda q, k, v: fm.flare_mixer_chunked(q, k, v, 1.0, chunk=4096)
        elif art.kind == "flare_pallas":
            fn = lambda q, k, v: fm.flare_mixer_pallas(q, k, v, 1.0)
        else:
            fn = lambda q, k, v: fm.flare_mixer_sdpa(q, k, v, 1.0)
        args = (_sds((h, m, d)), _sds((h, n, d)), _sds((h, n, d)))
    lowered = jax.jit(fn).lower(*args)
    fname = _write(out_dir, art.name, to_hlo_text(lowered))
    return {**dataclasses.asdict(art), "file": fname}


def lower_layer(art: LayerArtifact, out_dir: str) -> dict:
    cfg = ModelCfg(mixer=art.mixer, n=art.n, d_in=art.c, d_out=art.c,
                   c=art.c, heads=art.heads, m=art.m, blocks=1)
    spec = models.build_layer_spec(cfg)
    fn = lambda flat, x: models.layer_forward(cfg, spec, flat, x)
    lowered = jax.jit(fn).lower(_sds((spec.total,)), _sds((art.n, art.c)))
    fname = _write(out_dir, art.name, to_hlo_text(lowered))
    return {**dataclasses.asdict(art), "file": fname,
            "param_count": spec.total, "params": spec.to_manifest()}


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--group", default="all",
                    help="comma-separated groups (default: all)")
    args = ap.parse_args()
    groups = None if args.group == "all" else set(args.group.split(","))
    os.makedirs(args.out, exist_ok=True)

    t_start = time.time()
    manifest = {"version": 1, "seed": SEED, "datasets": DATASETS,
                "cases": [], "mixers": [], "layers": []}
    # partial regeneration (--group=...) merges into the existing manifest
    prior = {}
    mpath = os.path.join(args.out, "manifest.json")
    if groups and os.path.exists(mpath):
        with open(mpath) as f:
            prior = json.load(f)

    all_cases = cases_mod.build_cases()
    for i, case in enumerate(all_cases):
        if groups and case.group not in groups:
            continue
        t0 = time.time()
        manifest["cases"].append(lower_case(case, args.out))
        print(f"[{i + 1}/{len(all_cases)}] {case.name}: "
              f"{time.time() - t0:.1f}s", flush=True)

    for art in cases_mod.build_mixer_artifacts():
        if groups and art.group not in groups:
            continue
        t0 = time.time()
        manifest["mixers"].append(lower_mixer(art, args.out))
        print(f"[mixer] {art.name}: {time.time() - t0:.1f}s", flush=True)

    for art in cases_mod.build_layer_artifacts():
        if groups and art.group not in groups:
            continue
        t0 = time.time()
        manifest["layers"].append(lower_layer(art, args.out))
        print(f"[layer] {art.name}: {time.time() - t0:.1f}s", flush=True)

    if prior:
        fresh_cases = {c["name"] for c in manifest["cases"]}
        manifest["cases"].extend(
            c for c in prior.get("cases", []) if c["name"] not in fresh_cases)
        fresh_mx = {m["name"] for m in manifest["mixers"]}
        manifest["mixers"].extend(
            m for m in prior.get("mixers", []) if m["name"] not in fresh_mx)
        fresh_ly = {l["name"] for l in manifest["layers"]}
        manifest["layers"].extend(
            l for l in prior.get("layers", []) if l["name"] not in fresh_ly)

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    n_art = sum(len(c["artifacts"]) for c in manifest["cases"]) + \
        len(manifest["mixers"]) + len(manifest["layers"])
    print(f"wrote {n_art} artifacts + manifest.json in "
          f"{time.time() - t_start:.1f}s", flush=True)


if __name__ == "__main__":
    main()
