"""Layer-2 JAX models: FLARE and every baseline evaluated by the paper.

All models share the same input/output projections (paper Section D.3:
"the input and output projections ... are held consistent to facilitate an
equitable comparison of their point-to-point communication schemes") so that
Table 1 / Table 2 comparisons isolate the token-mixing operator.

Mixer families (``ModelCfg.mixer``):

* ``flare``       — the paper's contribution: two-SDPA encode/decode low-rank
                    routing, head-wise independent latent slices, deep ResMLP
                    K/V projections, no latent self-attention.  Supports the
                    Figure 11 hybrid (``latent_sa_blocks > 0``) and the
                    Figure 12 shared-latent ablation (``shared_latents``).
* ``vanilla``     — standard multi-head self-attention (O(N^2)).
* ``linformer``   — learned [M, N] projections of K/V (fixed token ordering).
* ``transolver``  — physics attention: soft slice assignment shared across
                    heads, self-attention over slices, de-slicing.
* ``perceiver``   — PerceiverIO-style encode -> latent self-attention stack
                    -> decode (latents as computational workspace).
* ``lno``         — LNO-style single encode/decode around a latent
                    transformer stack.

Every model is a pure function of a flat ``f32[P]`` parameter vector (see
:mod:`compile.packing`), which is what crosses the PJRT boundary to Rust.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Tuple

import jax
import jax.numpy as jnp

from .kernels import flare_mixer as fm
from .packing import ParamSpec
from .resmlp import (apply_layernorm, apply_linear, apply_resmlp,
                     declare_layernorm, declare_linear, declare_resmlp)

MIXERS = ("flare", "vanilla", "linformer", "transolver", "perceiver", "lno",
          "linatt", "performer", "gnot")


@dataclasses.dataclass(frozen=True)
class ModelCfg:
    """Static configuration of one model artifact (shapes baked into HLO)."""

    mixer: str = "flare"
    n: int = 1024            #: tokens per sample (static)
    d_in: int = 2
    d_out: int = 1
    c: int = 32              #: feature width C
    heads: int = 4           #: H; head dim D = C/H
    m: int = 32              #: latent tokens per head (FLARE) / latents (others)
    blocks: int = 2          #: B encode-decode (or SA) blocks
    kv_layers: int = 3       #: ResMLP depth for K/V projections (FLARE)
    ffn_layers: int = 3      #: ResMLP depth of the per-block feedforward
    io_layers: int = 2       #: ResMLP depth of input/output projections
    latent_sa_blocks: int = 0    #: L_B latent self-attention blocks (Fig 11)
    shared_latents: bool = False  #: share latent slice across heads (Fig 12)
    scale: float = 1.0       #: SDPA scale; paper uses 1.0 for FLARE
    mixer_impl: str = "sdpa"     #: sdpa | chunked | pallas
    task: str = "regression"     #: regression | classification
    vocab: int = 0
    num_classes: int = 0

    def __post_init__(self):
        if self.mixer not in MIXERS:
            raise ValueError(f"unknown mixer {self.mixer!r}")
        if self.c % self.heads:
            raise ValueError(f"C={self.c} not divisible by H={self.heads}")

    @property
    def head_dim(self) -> int:
        return self.c // self.heads


def _split_heads(x: jnp.ndarray, h: int) -> jnp.ndarray:
    """[N, C] -> [H, N, D]."""
    n, c = x.shape
    return x.reshape(n, h, c // h).transpose(1, 0, 2)


def _merge_heads(x: jnp.ndarray) -> jnp.ndarray:
    """[H, N, D] -> [N, C]."""
    h, n, d = x.shape
    return x.transpose(1, 0, 2).reshape(n, h * d)


def _sdpa(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, scale: float) -> jnp.ndarray:
    """Plain SDPA over leading head axis: [H, Nq, D] x [H, Nk, D]."""
    s = jnp.einsum("hqd,hkd->hqk", q, k) * scale
    return jnp.einsum("hqk,hkd->hqd", jax.nn.softmax(s, axis=-1), v)


# ---------------------------------------------------------------------------
# FLARE token mixer
# ---------------------------------------------------------------------------

def declare_flare_layer(spec: ParamSpec, p: str, cfg: ModelCfg) -> None:
    c, h, m, d = cfg.c, cfg.heads, cfg.m, cfg.head_dim
    declare_resmlp(spec, f"{p}.kproj", c, c, c, cfg.kv_layers)
    declare_resmlp(spec, f"{p}.vproj", c, c, c, cfg.kv_layers)
    if cfg.shared_latents:
        spec.add(f"{p}.latents", (m, d), "latent")
    else:
        spec.add(f"{p}.latents", (h, m, d), "latent")
    declare_linear(spec, f"{p}.out", c, c)
    for j in range(cfg.latent_sa_blocks):
        declare_layernorm(spec, f"{p}.lsa{j}.ln1", c)
        declare_linear(spec, f"{p}.lsa{j}.qkv", c, 3 * c)
        declare_linear(spec, f"{p}.lsa{j}.out", c, c)
        declare_layernorm(spec, f"{p}.lsa{j}.ln2", c)
        declare_resmlp(spec, f"{p}.lsa{j}.ffn", c, c, c, 1)


def apply_flare_layer(spec: ParamSpec, flat: jnp.ndarray, p: str,
                      x: jnp.ndarray, cfg: ModelCfg) -> jnp.ndarray:
    """FLARE token mixing on ``x [N, C]``."""
    c, h, m, d = cfg.c, cfg.heads, cfg.m, cfg.head_dim
    k = apply_resmlp(spec, flat, f"{p}.kproj", x, c, c, c, cfg.kv_layers)
    v = apply_resmlp(spec, flat, f"{p}.vproj", x, c, c, c, cfg.kv_layers)
    kh, vh = _split_heads(k, h), _split_heads(v, h)          # [H, N, D]
    q = spec.get(flat, f"{p}.latents")
    if cfg.shared_latents:
        q = jnp.broadcast_to(q[None], (h, m, d))

    if cfg.latent_sa_blocks == 0:
        mixer = fm.IMPLEMENTATIONS[cfg.mixer_impl]
        yh = mixer(q, kh, vh, cfg.scale)
    else:
        # Figure 11 hybrid: explicit encode -> latent SA stack -> decode.
        s = jnp.einsum("hmd,hnd->hmn", q, kh) * cfg.scale
        z = jnp.einsum("hmn,hnd->hmd", jax.nn.softmax(s, axis=-1), vh)
        zc = _merge_heads(z)                                  # [M, C]
        for j in range(cfg.latent_sa_blocks):
            pj = f"{p}.lsa{j}"
            zn = apply_layernorm(spec, flat, f"{pj}.ln1", zc)
            qkv = apply_linear(spec, flat, f"{pj}.qkv", zn)
            qq, kk, vv = jnp.split(qkv, 3, axis=-1)
            att = _sdpa(_split_heads(qq, h), _split_heads(kk, h),
                        _split_heads(vv, h), 1.0 / math.sqrt(d))
            zc = zc + apply_linear(spec, flat, f"{pj}.out", _merge_heads(att))
            zn = apply_layernorm(spec, flat, f"{pj}.ln2", zc)
            zc = zc + apply_resmlp(spec, flat, f"{pj}.ffn", zn, c, c, c, 1)
        z = _split_heads(zc, h)
        w = jax.nn.softmax(jnp.einsum("hnd,hmd->hnm", kh, q) * cfg.scale, axis=-1)
        yh = jnp.einsum("hnm,hmd->hnd", w, z)

    return apply_linear(spec, flat, f"{p}.out", _merge_heads(yh))


# ---------------------------------------------------------------------------
# Vanilla self-attention
# ---------------------------------------------------------------------------

def declare_vanilla_layer(spec: ParamSpec, p: str, cfg: ModelCfg) -> None:
    declare_linear(spec, f"{p}.qkv", cfg.c, 3 * cfg.c)
    declare_linear(spec, f"{p}.out", cfg.c, cfg.c)


def apply_vanilla_layer(spec: ParamSpec, flat: jnp.ndarray, p: str,
                        x: jnp.ndarray, cfg: ModelCfg) -> jnp.ndarray:
    qkv = apply_linear(spec, flat, f"{p}.qkv", x)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    y = _sdpa(_split_heads(q, cfg.heads), _split_heads(k, cfg.heads),
              _split_heads(v, cfg.heads), 1.0 / math.sqrt(cfg.head_dim))
    return apply_linear(spec, flat, f"{p}.out", _merge_heads(y))


# ---------------------------------------------------------------------------
# Linformer
# ---------------------------------------------------------------------------

def declare_linformer_layer(spec: ParamSpec, p: str, cfg: ModelCfg) -> None:
    declare_linear(spec, f"{p}.qkv", cfg.c, 3 * cfg.c)
    # learned [M, N] projections — the O(NM) parameter cost the paper calls out
    spec.add(f"{p}.ek", (cfg.m, cfg.n), "uniform_fanin", fan_in=cfg.n)
    spec.add(f"{p}.ev", (cfg.m, cfg.n), "uniform_fanin", fan_in=cfg.n)
    declare_linear(spec, f"{p}.out", cfg.c, cfg.c)


def apply_linformer_layer(spec: ParamSpec, flat: jnp.ndarray, p: str,
                          x: jnp.ndarray, cfg: ModelCfg) -> jnp.ndarray:
    qkv = apply_linear(spec, flat, f"{p}.qkv", x)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    k = spec.get(flat, f"{p}.ek") @ k           # [M, C]
    v = spec.get(flat, f"{p}.ev") @ v           # [M, C]
    y = _sdpa(_split_heads(q, cfg.heads), _split_heads(k, cfg.heads),
              _split_heads(v, cfg.heads), 1.0 / math.sqrt(cfg.head_dim))
    return apply_linear(spec, flat, f"{p}.out", _merge_heads(y))


# ---------------------------------------------------------------------------
# Transolver-style physics attention (w/o conv)
# ---------------------------------------------------------------------------

def declare_transolver_layer(spec: ParamSpec, p: str, cfg: ModelCfg) -> None:
    d = cfg.head_dim
    declare_linear(spec, f"{p}.xproj", cfg.c, cfg.c)
    # slice projection shared across heads (paper Fig. 6 footnote)
    spec.add(f"{p}.wslice", (d, cfg.m), "uniform_fanin", fan_in=d)
    declare_linear(spec, f"{p}.q", cfg.c, cfg.c)
    declare_linear(spec, f"{p}.k", cfg.c, cfg.c)
    declare_linear(spec, f"{p}.v", cfg.c, cfg.c)
    declare_linear(spec, f"{p}.out", cfg.c, cfg.c)


def apply_transolver_layer(spec: ParamSpec, flat: jnp.ndarray, p: str,
                           x: jnp.ndarray, cfg: ModelCfg) -> jnp.ndarray:
    h, d, m = cfg.heads, cfg.head_dim, cfg.m
    xh = _split_heads(apply_linear(spec, flat, f"{p}.xproj", x), h)  # [H, N, D]
    ws = spec.get(flat, f"{p}.wslice")                               # [D, M]
    w = jax.nn.softmax(jnp.einsum("hnd,dm->hnm", xh, ws), axis=-1)   # [H, N, M]
    denom = jnp.sum(w, axis=1, keepdims=True)                        # [H, 1, M]
    z = jnp.einsum("hnm,hnd->hmd", w, xh) / denom.transpose(0, 2, 1)  # [H, M, D]
    zc = _merge_heads(z)                                             # [M, C]
    q = _split_heads(apply_linear(spec, flat, f"{p}.q", zc), h)
    k = _split_heads(apply_linear(spec, flat, f"{p}.k", zc), h)
    v = _split_heads(apply_linear(spec, flat, f"{p}.v", zc), h)
    z2 = _sdpa(q, k, v, 1.0 / math.sqrt(d))                          # [H, M, D]
    y = jnp.einsum("hnm,hmd->hnd", w, z2)                            # de-slice
    return apply_linear(spec, flat, f"{p}.out", _merge_heads(y))


# ---------------------------------------------------------------------------
# Linear attention (Katharopoulos-style, Table 2 baseline)
# ---------------------------------------------------------------------------

def declare_linatt_layer(spec: ParamSpec, p: str, cfg: ModelCfg) -> None:
    declare_linear(spec, f"{p}.qkv", cfg.c, 3 * cfg.c)
    declare_linear(spec, f"{p}.out", cfg.c, cfg.c)


def apply_linatt_layer(spec: ParamSpec, flat: jnp.ndarray, p: str,
                       x: jnp.ndarray, cfg: ModelCfg) -> jnp.ndarray:
    """O(N) attention with feature map phi = elu + 1 (non-causal)."""
    qkv = apply_linear(spec, flat, f"{p}.qkv", x)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    qh = jax.nn.elu(_split_heads(q, cfg.heads)) + 1.0       # [H, N, D]
    kh = jax.nn.elu(_split_heads(k, cfg.heads)) + 1.0
    vh = _split_heads(v, cfg.heads)
    kv = jnp.einsum("hnd,hne->hde", kh, vh)                  # [H, D, D]
    ksum = jnp.sum(kh, axis=1)                               # [H, D]
    num = jnp.einsum("hnd,hde->hne", qh, kv)
    den = jnp.einsum("hnd,hd->hn", qh, ksum) + 1e-6
    y = num / den[:, :, None]
    return apply_linear(spec, flat, f"{p}.out", _merge_heads(y))


# ---------------------------------------------------------------------------
# Performer (FAVOR+-style positive random features, Table 2 baseline)
# ---------------------------------------------------------------------------

def declare_performer_layer(spec: ParamSpec, p: str, cfg: ModelCfg) -> None:
    declare_linear(spec, f"{p}.qkv", cfg.c, 3 * cfg.c)
    # random-feature projection; drawn from the init stream and trained
    # (orthogonal redraw omitted — documented substitution in DESIGN.md)
    spec.add(f"{p}.omega", (cfg.head_dim, cfg.m), "uniform_fanin", fan_in=cfg.head_dim)
    declare_linear(spec, f"{p}.out", cfg.c, cfg.c)


def apply_performer_layer(spec: ParamSpec, flat: jnp.ndarray, p: str,
                          x: jnp.ndarray, cfg: ModelCfg) -> jnp.ndarray:
    qkv = apply_linear(spec, flat, f"{p}.qkv", x)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    d = cfg.head_dim
    qh = _split_heads(q, cfg.heads) / (d ** 0.25)
    kh = _split_heads(k, cfg.heads) / (d ** 0.25)
    vh = _split_heads(v, cfg.heads)
    omega = spec.get(flat, f"{p}.omega")                      # [D, R]

    def phi(u):
        proj = jnp.einsum("hnd,dr->hnr", u, omega)
        sq = 0.5 * jnp.sum(jnp.square(u), axis=-1, keepdims=True)
        return jnp.exp(proj - sq - jnp.max(proj, axis=-1, keepdims=True)) + 1e-6

    qf, kf = phi(qh), phi(kh)                                 # [H, N, R]
    kv = jnp.einsum("hnr,hnd->hrd", kf, vh)
    ksum = jnp.sum(kf, axis=1)                                # [H, R]
    num = jnp.einsum("hnr,hrd->hnd", qf, kv)
    den = jnp.einsum("hnr,hr->hn", qf, ksum) + 1e-6
    y = num / den[:, :, None]
    return apply_linear(spec, flat, f"{p}.out", _merge_heads(y))


# ---------------------------------------------------------------------------
# GNOT-style normalized linear attention with gating (Table 1 baseline)
# ---------------------------------------------------------------------------

def declare_gnot_layer(spec: ParamSpec, p: str, cfg: ModelCfg) -> None:
    declare_linear(spec, f"{p}.qkv", cfg.c, 3 * cfg.c)
    declare_linear(spec, f"{p}.gate1", cfg.c, cfg.c)
    declare_linear(spec, f"{p}.gate2", cfg.c, cfg.c)
    declare_linear(spec, f"{p}.out", cfg.c, cfg.c)


def apply_gnot_layer(spec: ParamSpec, flat: jnp.ndarray, p: str,
                     x: jnp.ndarray, cfg: ModelCfg) -> jnp.ndarray:
    """Heterogeneous *normalized* attention: softmax applied separately to
    queries and keys, giving an O(N) two-stage aggregation, gated by a
    geometry MLP (simplified single-expert GNOT)."""
    qkv = apply_linear(spec, flat, f"{p}.qkv", x)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    qh = jax.nn.softmax(_split_heads(q, cfg.heads), axis=-1)  # over D
    kh = jax.nn.softmax(_split_heads(k, cfg.heads), axis=1)   # over N
    vh = _split_heads(v, cfg.heads)
    kv = jnp.einsum("hnd,hne->hde", kh, vh)
    y = jnp.einsum("hnd,hde->hne", qh, kv)
    gate = jax.nn.sigmoid(apply_linear(
        spec, flat, f"{p}.gate2",
        jax.nn.gelu(apply_linear(spec, flat, f"{p}.gate1", x))))
    return apply_linear(spec, flat, f"{p}.out", _merge_heads(y)) * gate


# ---------------------------------------------------------------------------
# Cross-attention (Perceiver / LNO skeleton)
# ---------------------------------------------------------------------------

def declare_cross_attn(spec: ParamSpec, p: str, cfg: ModelCfg) -> None:
    declare_linear(spec, f"{p}.q", cfg.c, cfg.c)
    declare_linear(spec, f"{p}.k", cfg.c, cfg.c)
    declare_linear(spec, f"{p}.v", cfg.c, cfg.c)
    declare_linear(spec, f"{p}.out", cfg.c, cfg.c)


def apply_cross_attn(spec: ParamSpec, flat: jnp.ndarray, p: str,
                     xq: jnp.ndarray, xkv: jnp.ndarray, cfg: ModelCfg) -> jnp.ndarray:
    h, d = cfg.heads, cfg.head_dim
    q = _split_heads(apply_linear(spec, flat, f"{p}.q", xq), h)
    k = _split_heads(apply_linear(spec, flat, f"{p}.k", xkv), h)
    v = _split_heads(apply_linear(spec, flat, f"{p}.v", xkv), h)
    y = _sdpa(q, k, v, 1.0 / math.sqrt(d))
    return apply_linear(spec, flat, f"{p}.out", _merge_heads(y))


def declare_sa_block(spec: ParamSpec, p: str, cfg: ModelCfg) -> None:
    declare_layernorm(spec, f"{p}.ln1", cfg.c)
    declare_linear(spec, f"{p}.qkv", cfg.c, 3 * cfg.c)
    declare_linear(spec, f"{p}.att_out", cfg.c, cfg.c)
    declare_layernorm(spec, f"{p}.ln2", cfg.c)
    declare_resmlp(spec, f"{p}.ffn", cfg.c, cfg.c, cfg.c, cfg.ffn_layers)


def apply_sa_block(spec: ParamSpec, flat: jnp.ndarray, p: str,
                   x: jnp.ndarray, cfg: ModelCfg) -> jnp.ndarray:
    xn = apply_layernorm(spec, flat, f"{p}.ln1", x)
    qkv = apply_linear(spec, flat, f"{p}.qkv", xn)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    att = _sdpa(_split_heads(q, cfg.heads), _split_heads(k, cfg.heads),
                _split_heads(v, cfg.heads), 1.0 / math.sqrt(cfg.head_dim))
    x = x + apply_linear(spec, flat, f"{p}.att_out", _merge_heads(att))
    xn = apply_layernorm(spec, flat, f"{p}.ln2", x)
    return x + apply_resmlp(spec, flat, f"{p}.ffn", xn, cfg.c, cfg.c, cfg.c,
                            cfg.ffn_layers)


# ---------------------------------------------------------------------------
# Whole-model declaration / forward
# ---------------------------------------------------------------------------

_PER_BLOCK = {
    "flare": (declare_flare_layer, apply_flare_layer),
    "vanilla": (declare_vanilla_layer, apply_vanilla_layer),
    "linformer": (declare_linformer_layer, apply_linformer_layer),
    "transolver": (declare_transolver_layer, apply_transolver_layer),
    "linatt": (declare_linatt_layer, apply_linatt_layer),
    "performer": (declare_performer_layer, apply_performer_layer),
    "gnot": (declare_gnot_layer, apply_gnot_layer),
}


def build_layer_spec(cfg: ModelCfg) -> ParamSpec:
    """Spec for a *single bare mixing layer* (Figure 8 benchmarks)."""
    if cfg.mixer not in _PER_BLOCK:
        raise ValueError(f"{cfg.mixer} has no bare-layer form")
    spec = ParamSpec()
    _PER_BLOCK[cfg.mixer][0](spec, "layer", cfg)
    return spec


def layer_forward(cfg: ModelCfg, spec: ParamSpec, flat: jnp.ndarray,
                  x: jnp.ndarray) -> jnp.ndarray:
    """Forward of a single bare mixing layer on ``x [N, C]``."""
    return _PER_BLOCK[cfg.mixer][1](spec, flat, "layer", x, cfg)


def qk_forward(cfg: ModelCfg, spec: ParamSpec, flat: jnp.ndarray,
               x: jnp.ndarray):
    """Extract per-block head keys for the spectral analysis (Figure 12).

    Returns a tuple with one ``[H, N, D]`` key tensor per FLARE block,
    evaluated at the block's actual input activations.  The latent queries
    are parameters; Rust reads them from the flat vector via the manifest.
    """
    if cfg.mixer != "flare":
        raise ValueError("qk extraction only defined for FLARE")
    c = cfg.c
    h = apply_resmlp(spec, flat, "in_proj", x, cfg.d_in, c, c, cfg.io_layers)
    ks = []
    for b in range(cfg.blocks):
        hn = apply_layernorm(spec, flat, f"blk{b}.ln1", h)
        k = apply_resmlp(spec, flat, f"blk{b}.mix.kproj", hn, c, c, c, cfg.kv_layers)
        ks.append(_split_heads(k, cfg.heads))
        h = h + apply_flare_layer(spec, flat, f"blk{b}.mix", hn, cfg)
        hn = apply_layernorm(spec, flat, f"blk{b}.ln2", h)
        h = h + apply_resmlp(spec, flat, f"blk{b}.ffn", hn, c, c, c, cfg.ffn_layers)
    return tuple(ks)


def build_spec(cfg: ModelCfg) -> ParamSpec:
    """Declare every parameter of the model described by ``cfg``."""
    spec = ParamSpec()
    c = cfg.c

    # input projection (or embedding for token tasks)
    if cfg.task == "classification":
        spec.add("embed", (cfg.vocab, c), "embedding")
    else:
        declare_resmlp(spec, "in_proj", cfg.d_in, c, c, cfg.io_layers)

    if cfg.mixer in _PER_BLOCK:
        declare = _PER_BLOCK[cfg.mixer][0]
        for b in range(cfg.blocks):
            declare_layernorm(spec, f"blk{b}.ln1", c)
            declare(spec, f"blk{b}.mix", cfg)
            declare_layernorm(spec, f"blk{b}.ln2", c)
            declare_resmlp(spec, f"blk{b}.ffn", c, c, c, cfg.ffn_layers)
    else:  # perceiver / lno: encode -> latent stack -> decode
        spec.add("latent_array", (cfg.m, c), "latent")
        declare_cross_attn(spec, "encode", cfg)
        declare_layernorm(spec, "encode.ln", c)
        n_latent = cfg.latent_sa_blocks if cfg.latent_sa_blocks else cfg.blocks
        for b in range(n_latent):
            declare_sa_block(spec, f"lat{b}", cfg)
        declare_cross_attn(spec, "decode", cfg)
        declare_layernorm(spec, "decode.ln", c)

    declare_layernorm(spec, "out_ln", c)
    if cfg.task == "classification":
        declare_linear(spec, "cls_head", c, cfg.num_classes)
    else:
        declare_resmlp(spec, "out_proj", c, c, cfg.d_out, cfg.io_layers)
    return spec


def forward(cfg: ModelCfg, spec: ParamSpec, flat: jnp.ndarray,
            x: jnp.ndarray) -> jnp.ndarray:
    """Single-sample forward.

    Regression: ``x [N, d_in] -> [N, d_out]``.
    Classification: ``x int32 [N] -> logits [num_classes]``.
    """
    c = cfg.c
    if cfg.task == "classification":
        h = jnp.take(spec.get(flat, "embed"), x, axis=0)      # [N, C]
    else:
        h = apply_resmlp(spec, flat, "in_proj", x, cfg.d_in, c, c, cfg.io_layers)

    if cfg.mixer in _PER_BLOCK:
        apply = _PER_BLOCK[cfg.mixer][1]
        for b in range(cfg.blocks):
            hn = apply_layernorm(spec, flat, f"blk{b}.ln1", h)
            h = h + apply(spec, flat, f"blk{b}.mix", hn, cfg)
            hn = apply_layernorm(spec, flat, f"blk{b}.ln2", h)
            h = h + apply_resmlp(spec, flat, f"blk{b}.ffn", hn, c, c, c,
                                 cfg.ffn_layers)
    else:
        lat = jnp.broadcast_to(spec.get(flat, "latent_array"), (cfg.m, c))
        lat = lat + apply_cross_attn(
            spec, flat, "encode",
            apply_layernorm(spec, flat, "encode.ln", lat), h, cfg)
        n_latent = cfg.latent_sa_blocks if cfg.latent_sa_blocks else cfg.blocks
        for b in range(n_latent):
            lat = apply_sa_block(spec, flat, f"lat{b}", lat, cfg)
        h = h + apply_cross_attn(
            spec, flat, "decode",
            apply_layernorm(spec, flat, "decode.ln", h), lat, cfg)

    h = apply_layernorm(spec, flat, "out_ln", h)
    if cfg.task == "classification":
        pooled = jnp.mean(h, axis=0)
        return apply_linear(spec, flat, "cls_head", pooled)
    return apply_resmlp(spec, flat, "out_proj", h, c, c, cfg.d_out,
                        cfg.io_layers)


def forward_batched(cfg: ModelCfg, spec: ParamSpec, flat: jnp.ndarray,
                    x: jnp.ndarray) -> jnp.ndarray:
    """vmap of :func:`forward` over the leading batch axis."""
    return jax.vmap(lambda xi: forward(cfg, spec, flat, xi))(x)


def param_count(cfg: ModelCfg) -> int:
    return build_spec(cfg).total
