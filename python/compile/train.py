"""Training-step construction: loss, AdamW, and the fused step function.

The whole optimizer lives inside one jitted function so Rust drives training
with a single ``execute`` per step:

    (params, m, v, step, lr, x, y) -> (params', m', v', loss)

All optimizer state is flat ``f32[P]``; the learning rate is an input so the
OneCycle schedule (paper Section D.3) is computed by the Rust Layer-3
coordinator (``rust/src/train/schedule.rs``) — python stays off the training
hot path entirely.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Tuple

import jax
import jax.numpy as jnp

from . import models
from .models import ModelCfg
from .packing import ParamSpec


@dataclasses.dataclass(frozen=True)
class OptCfg:
    """AdamW hyperparameters (paper Section D.3 defaults)."""

    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 1e-5
    grad_clip: float = 1.0   #: global-norm clip; paper uses max_norm = 1.0


def rel_l2_loss(pred: jnp.ndarray, target: jnp.ndarray) -> jnp.ndarray:
    """Relative L2 (paper Eq. 21/22), averaged over the batch axis."""
    axes = tuple(range(1, pred.ndim))
    num = jnp.sqrt(jnp.sum(jnp.square(pred - target), axis=axes))
    den = jnp.sqrt(jnp.sum(jnp.square(target), axis=axes)) + 1e-12
    return jnp.mean(num / den)


def cross_entropy_loss(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Softmax cross entropy; ``logits [B, K]``, ``labels int32 [B]``."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))


def make_loss_fn(cfg: ModelCfg, spec: ParamSpec) -> Callable:
    def loss_fn(flat, x, y):
        pred = models.forward_batched(cfg, spec, flat, x)
        if cfg.task == "classification":
            return cross_entropy_loss(pred, y)
        return rel_l2_loss(pred, y)
    return loss_fn


def make_forward_fn(cfg: ModelCfg, spec: ParamSpec) -> Callable:
    def fwd(flat, x):
        return models.forward_batched(cfg, spec, flat, x)
    return fwd


def make_train_step(cfg: ModelCfg, spec: ParamSpec, opt: OptCfg) -> Callable:
    """Build the fused AdamW train step (donatable flat buffers)."""
    loss_fn = make_loss_fn(cfg, spec)

    def train_step(params: jnp.ndarray, m: jnp.ndarray, v: jnp.ndarray,
                   step: jnp.ndarray, lr: jnp.ndarray,
                   x: jnp.ndarray, y: jnp.ndarray
                   ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        loss, g = jax.value_and_grad(loss_fn)(params, x, y)
        # global-norm gradient clipping
        gnorm = jnp.sqrt(jnp.sum(jnp.square(g)))
        g = g * jnp.minimum(1.0, opt.grad_clip / (gnorm + 1e-12))
        m = opt.beta1 * m + (1.0 - opt.beta1) * g
        v = opt.beta2 * v + (1.0 - opt.beta2) * jnp.square(g)
        t = step + 1.0
        mhat = m / (1.0 - opt.beta1 ** t)
        vhat = v / (1.0 - opt.beta2 ** t)
        update = mhat / (jnp.sqrt(vhat) + opt.eps) + opt.weight_decay * params
        params = params - lr * update
        return params, m, v, loss

    return train_step


def make_eval_fn(cfg: ModelCfg, spec: ParamSpec) -> Callable:
    """Evaluation: returns per-batch mean metric (rel-L2 or accuracy)."""
    def eval_fn(flat, x, y):
        pred = models.forward_batched(cfg, spec, flat, x)
        if cfg.task == "classification":
            return jnp.mean((jnp.argmax(pred, axis=-1) == y).astype(jnp.float32))
        return rel_l2_loss(pred, y)
    return eval_fn
