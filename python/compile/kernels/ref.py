"""Pure-jnp oracles for the FLARE token mixer (paper Figure 7).

These materialize the M x N encode and N x M decode score matrices
explicitly, exactly as the paper's "no fused kernel" pseudocode does.  They
are the correctness reference for both the Pallas kernel
(:mod:`compile.kernels.flare_mixer`) and the chunked SDPA implementation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def flare_mixer_head_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                         scale: float = 1.0) -> jnp.ndarray:
    """Single-head FLARE mixer, dense reference.

    Args:
      q: latent queries ``[M, D]`` (learned, input independent).
      k: keys ``[N, D]``.
      v: values ``[N, D]``.
      scale: SDPA scale (paper uses 1.0).

    Returns:
      ``[N, D]`` mixed output ``Y = softmax(K Q^T) softmax(Q K^T) V``.
    """
    s = jnp.matmul(q, k.T) * scale                      # [M, N]
    w_enc = jax.nn.softmax(s, axis=-1)                  # rows over N
    z = jnp.matmul(w_enc, v)                            # [M, D]
    w_dec = jax.nn.softmax(jnp.matmul(k, q.T) * scale, axis=-1)  # [N, M]
    return jnp.matmul(w_dec, z)                         # [N, D]


def flare_mixer_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    scale: float = 1.0) -> jnp.ndarray:
    """Multi-head FLARE mixer, dense reference.

    Args:
      q: ``[H, M, D]`` latent queries (head-wise independent slices).
      k, v: ``[H, N, D]`` per-head keys / values.

    Returns:
      ``[H, N, D]``.
    """
    return jax.vmap(flare_mixer_head_ref, in_axes=(0, 0, 0, None))(q, k, v, scale)


def mixing_matrix_ref(q: jnp.ndarray, k: jnp.ndarray,
                      scale: float = 1.0) -> jnp.ndarray:
    """The induced rank-<=M input-input operator ``W_h`` (paper Eq. 9).

    Args:
      q: ``[M, D]``, k: ``[N, D]``.
    Returns:
      ``W = W_dec @ W_enc`` of shape ``[N, N]``.
    """
    w_enc = jax.nn.softmax(jnp.matmul(q, k.T) * scale, axis=-1)   # [M, N]
    w_dec = jax.nn.softmax(jnp.matmul(k, q.T) * scale, axis=-1)   # [N, M]
    return jnp.matmul(w_dec, w_enc)


def eig_lowrank_ref(q: jnp.ndarray, k: jnp.ndarray, scale: float = 1.0):
    """Paper Algorithm 1: eigendecomposition of W in O(M^3 + M^2 N).

    Returns ``(eigvals [M], eigvecs [N, M])`` with eigenvalues sorted
    descending.  Used to cross-check the Rust implementation in
    ``rust/src/spectral/``.
    """
    s = jnp.matmul(q, k.T) * scale                       # [M, N]
    # A global scalar shift keeps exp() finite; W is invariant to it because
    # both row and column normalizations absorb the common factor.
    s = s - jnp.max(s)
    a = jnp.exp(s)                                       # [M, N]
    # clamp the normalizers: with extreme scores whole columns can
    # underflow to zero after the global shift
    lam_m = 1.0 / jnp.maximum(jnp.sum(a, axis=1), 1e-30)  # [M]
    lam_n = 1.0 / jnp.maximum(jnp.sum(a, axis=0), 1e-30)  # [N]
    j = jnp.sqrt(lam_m)[:, None] * a * jnp.sqrt(lam_n)[None, :]   # [M, N]
    jjt = jnp.matmul(j, j.T)                             # [M, M]
    evals, u = jnp.linalg.eigh(jjt)                      # ascending
    evals = evals[::-1]
    u = u[:, ::-1]
    # eigvecs of W: Lambda_N^{1/2} J^T U Sigma^{-1}
    sigma = jnp.sqrt(jnp.maximum(evals, 1e-30))
    vecs = jnp.sqrt(lam_n)[:, None] * jnp.matmul(j.T, u) / sigma[None, :]
    return evals, vecs
