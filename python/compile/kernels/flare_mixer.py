"""Layer-1 Pallas kernel: the FLARE encode-decode token mixer.

The paper's hot spot is two SDPA calls per head:

    Z_h = SDPA(Q_h, K_h, V_h, s=1)      # encode [M,D] x [N,D] -> [M,D]
    Y_h = SDPA(K_h, Q_h, Z_h, s=1)      # decode [N,D] x [M,D] -> [N,D]

TPU adaptation (DESIGN.md section "Hardware-Adaptation"): instead of porting a
CUDA FlashAttention schedule, the latent state is the resident operand.  For
each (head) program the latent accumulators — running max ``m [M]``, softmax
denominator ``den [M]`` and weighted sum ``acc [M,D]`` — live in VMEM scratch
for the whole kernel while ``K``/``V`` stream through in N-tiles:

  pass 0 (encode): online-softmax accumulation of exp(Q K_t^T) V_t,
  pass 1 (decode): re-stream K tiles, full-row softmax over the (small,
                   fully-resident) M latent axis, write Y tiles.

Grid is ``(H, 2, N/tile)``; Pallas executes the grid sequentially per core so
scratch carries encode state into the decode pass.  VMEM footprint per
program is O(M*D + tile*D), independent of N.

``interpret=True`` is mandatory here: the CPU PJRT client cannot execute
Mosaic custom-calls, and this repo validates numerics through the interpret
path (pytest vs :mod:`compile.kernels.ref`).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _flare_kernel(q_ref, k_ref, v_ref, y_ref, m_ref, den_ref, acc_ref, *,
                  scale: float, n_actual: int, tile: int):
    """Kernel body for one (head, pass, tile) grid step."""
    p = pl.program_id(1)      # 0 = encode accumulation, 1 = decode
    i = pl.program_id(2)      # tile index along N

    q = q_ref[0]                                # [M, D]
    k = k_ref[0]                                # [tile, D]

    # mask for ragged final tile (static N, static tile)
    col = i * tile + jax.lax.broadcasted_iota(jnp.int32, (tile,), 0)
    valid = col < n_actual                      # [tile]

    @pl.when(jnp.logical_and(p == 0, i == 0))
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        den_ref[...] = jnp.zeros_like(den_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(p == 0)
    def _encode():
        v = v_ref[0]                            # [tile, D]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # [M, tile]
        s = jnp.where(valid[None, :], s, _NEG_INF)
        m_old = m_ref[...]                      # [M]
        m_new = jnp.maximum(m_old, jnp.max(s, axis=1))
        corr = jnp.exp(m_old - m_new)           # rescale old accumulators
        e = jnp.exp(s - m_new[:, None])         # [M, tile]
        e = jnp.where(valid[None, :], e, 0.0)
        den_ref[...] = den_ref[...] * corr + jnp.sum(e, axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(
            e, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(p == 1)
    def _decode():
        z = acc_ref[...] / den_ref[...][:, None]            # [M, D]
        logits = jnp.dot(k, q.T, preferred_element_type=jnp.float32) * scale  # [tile, M]
        # full M axis resident: ordinary row softmax, no streaming needed
        logits = logits - jnp.max(logits, axis=1, keepdims=True)
        w = jnp.exp(logits)
        w = w / jnp.sum(w, axis=1, keepdims=True)
        y_ref[0] = jnp.dot(w, z, preferred_element_type=jnp.float32)


def flare_mixer_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                       scale: float = 1.0, tile: int = 512,
                       interpret: bool = True) -> jnp.ndarray:
    """Multi-head FLARE mixer as a two-pass streaming Pallas kernel.

    Args:
      q: ``[H, M, D]`` latent queries.
      k, v: ``[H, N, D]`` per-head keys/values.
      scale: SDPA scale; the paper uses 1.0.
      tile: N-tile size streamed through VMEM.
      interpret: must stay True on CPU PJRT (Mosaic custom-calls cannot run).

    Returns:
      ``[H, N, D]`` mixed outputs, numerically matching
      :func:`compile.kernels.ref.flare_mixer_ref` to f32 tolerance.
    """
    h, m, d = q.shape
    hk, n, dk = k.shape
    if (hk, dk) != (h, d) or v.shape != k.shape:
        raise ValueError(f"shape mismatch q={q.shape} k={k.shape} v={v.shape}")
    tile = min(tile, max(n, 1))
    n_tiles = -(-n // tile)
    n_pad = n_tiles * tile
    if n_pad != n:
        pad = [(0, 0), (0, n_pad - n), (0, 0)]
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)

    kernel = functools.partial(_flare_kernel, scale=scale, n_actual=n, tile=tile)
    y = pl.pallas_call(
        kernel,
        grid=(h, 2, n_tiles),
        in_specs=[
            pl.BlockSpec((1, m, d), lambda hh, p, i: (hh, 0, 0)),
            pl.BlockSpec((1, tile, d), lambda hh, p, i: (hh, i, 0)),
            pl.BlockSpec((1, tile, d), lambda hh, p, i: (hh, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile, d), lambda hh, p, i: (hh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((h, n_pad, d), jnp.float32),
        scratch_shapes=[
            # VMEM-resident latent state (interpret mode emulates this)
            pltpu.VMEM((m,), jnp.float32),
            pltpu.VMEM((m,), jnp.float32),
            pltpu.VMEM((m, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return y[:, :n, :]


def flare_mixer_chunked(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        scale: float = 1.0, chunk: int = 4096) -> jnp.ndarray:
    """O(NM) mixer with bounded memory, pure jnp (XLA-fusable fallback).

    Streams N in ``chunk`` blocks with an online softmax for the encode pass
    (same math as the Pallas kernel) and a scanned decode.  Used by Layer-2
    model artifacts at very large N where materializing ``[H, M, N]`` scores
    at once would exceed host memory.
    """
    h, m, d = q.shape
    _, n, _ = k.shape
    n_chunks = -(-n // chunk)
    n_pad = n_chunks * chunk
    if n_pad != n:
        k = jnp.pad(k, [(0, 0), (0, n_pad - n), (0, 0)])
        v = jnp.pad(v, [(0, 0), (0, n_pad - n), (0, 0)])
    kc = k.reshape(h, n_chunks, chunk, d)
    vc = v.reshape(h, n_chunks, chunk, d)
    base = jnp.arange(n_chunks) * chunk
    col = jnp.arange(chunk)

    def encode_step(carry, xs):
        m_run, den, acc = carry
        kt, vt, b = xs
        s = jnp.einsum("hmd,hcd->hmc", q, kt) * scale
        mask = (b + col)[None, None, :] < n
        s = jnp.where(mask, s, _NEG_INF)
        m_new = jnp.maximum(m_run, jnp.max(s, axis=2))
        corr = jnp.exp(m_run - m_new)
        e = jnp.where(mask, jnp.exp(s - m_new[:, :, None]), 0.0)
        den = den * corr + jnp.sum(e, axis=2)
        acc = acc * corr[:, :, None] + jnp.einsum("hmc,hcd->hmd", e, vt)
        return (m_new, den, acc), None

    init = (jnp.full((h, m), _NEG_INF), jnp.zeros((h, m)), jnp.zeros((h, m, d)))
    (_, den, acc), _ = jax.lax.scan(
        encode_step, init, (kc.transpose(1, 0, 2, 3), vc.transpose(1, 0, 2, 3), base))
    z = acc / den[:, :, None]                              # [H, M, D]

    def decode_step(_, kt):
        logits = jnp.einsum("hcd,hmd->hcm", kt, q) * scale
        w = jax.nn.softmax(logits, axis=-1)
        return None, jnp.einsum("hcm,hmd->hcd", w, z)

    _, yc = jax.lax.scan(decode_step, None, kc.transpose(1, 0, 2, 3))
    y = yc.transpose(1, 0, 2, 3).reshape(h, n_pad, d)
    return y[:, :n, :]


def flare_mixer_sdpa(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     scale: float = 1.0) -> jnp.ndarray:
    """Dense jnp mixer (two softmax matmul chains) — the Layer-2 default.

    XLA fuses the [M,N] score materialization; fine for the moderate-N
    training artifacts.  Identical math to :func:`ref.flare_mixer_ref` but
    kept here so model code depends only on this module.
    """
    s = jnp.einsum("hmd,hnd->hmn", q, k) * scale
    z = jnp.einsum("hmn,hnd->hmd", jax.nn.softmax(s, axis=-1), v)
    w = jax.nn.softmax(jnp.swapaxes(s, 1, 2), axis=-1)     # [H, N, M]
    return jnp.einsum("hnm,hmd->hnd", w, z)


IMPLEMENTATIONS = {
    "pallas": flare_mixer_pallas,
    "chunked": flare_mixer_chunked,
    "sdpa": flare_mixer_sdpa,
}
