"""Counter-based SplitMix64 stream shared bit-for-bit with Rust.

``u01(seed, counter)`` must agree exactly with ``flare::util::rng::u01`` on
the Rust side: both compute ``splitmix64(seed ^ GOLDEN*counter)`` and take the
top 24 bits as a dyadic rational in [0, 1).  All arithmetic is mod 2^64.
"""

from __future__ import annotations

import numpy as np

_GOLDEN = np.uint64(0x9E3779B97F4A7C15)
_M1 = np.uint64(0xBF58476D1CE4E5B9)
_M2 = np.uint64(0x94D049BB133111EB)


def splitmix64(x: np.ndarray) -> np.ndarray:
    """Vectorized SplitMix64 finalizer over uint64 arrays."""
    with np.errstate(over="ignore"):
        z = (x + _GOLDEN).astype(np.uint64)
        z = ((z ^ (z >> np.uint64(30))) * _M1).astype(np.uint64)
        z = ((z ^ (z >> np.uint64(27))) * _M2).astype(np.uint64)
        return (z ^ (z >> np.uint64(31))).astype(np.uint64)


def u01(seed: int, counter: np.ndarray) -> np.ndarray:
    """Uniform [0,1) doubles from (seed, counter) pairs.

    24-bit mantissa so the f64 -> f32 cast downstream is exact.
    """
    counter = np.asarray(counter, dtype=np.uint64)
    with np.errstate(over="ignore"):
        key = (np.uint64(seed) ^ (counter * _GOLDEN)).astype(np.uint64)
    bits = splitmix64(key) >> np.uint64(40)  # top 24 bits
    return bits.astype(np.float64) / float(1 << 24)
