"""Residual MLP building block (paper Appendix B).

Structure for ``ResMLP(C_i, C_h, C_o, L)``:

  1. linear ``C_i -> C_h``; input residual added when ``C_i == C_h``;
  2. ``L`` residual layers, each ``h = h + GELU(h W + b)``;
  3. linear ``C_h -> C_o``; output residual added when ``C_h == C_o``.

These are the only pointwise nonlinearities in the model.  Parameters are
registered on a :class:`compile.packing.ParamSpec` under a name prefix so the
flat-vector layout is reproducible from the manifest alone.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .packing import ParamSpec


def declare_resmlp(spec: ParamSpec, prefix: str, c_in: int, c_hidden: int,
                   c_out: int, layers: int) -> None:
    """Register ResMLP parameters on ``spec`` under ``prefix``."""
    spec.add(f"{prefix}.win", (c_in, c_hidden), "uniform_fanin", fan_in=c_in)
    spec.add(f"{prefix}.bin", (c_hidden,), "zeros")
    for l in range(layers):
        spec.add(f"{prefix}.w{l}", (c_hidden, c_hidden), "uniform_fanin", fan_in=c_hidden)
        spec.add(f"{prefix}.b{l}", (c_hidden,), "zeros")
    spec.add(f"{prefix}.wout", (c_hidden, c_out), "uniform_fanin", fan_in=c_hidden)
    spec.add(f"{prefix}.bout", (c_out,), "zeros")


def apply_resmlp(spec: ParamSpec, flat: jnp.ndarray, prefix: str,
                 x: jnp.ndarray, c_in: int, c_hidden: int, c_out: int,
                 layers: int) -> jnp.ndarray:
    """Apply the ResMLP to ``x [..., C_i]`` -> ``[..., C_o]``."""
    h = x @ spec.get(flat, f"{prefix}.win") + spec.get(flat, f"{prefix}.bin")
    if c_in == c_hidden:
        h = h + x
    for l in range(layers):
        w = spec.get(flat, f"{prefix}.w{l}")
        b = spec.get(flat, f"{prefix}.b{l}")
        h = h + jax.nn.gelu(h @ w + b)
    y = h @ spec.get(flat, f"{prefix}.wout") + spec.get(flat, f"{prefix}.bout")
    if c_hidden == c_out:
        y = y + h
    return y


def declare_layernorm(spec: ParamSpec, prefix: str, c: int) -> None:
    spec.add(f"{prefix}.gamma", (c,), "ones")
    spec.add(f"{prefix}.beta", (c,), "zeros")


def apply_layernorm(spec: ParamSpec, flat: jnp.ndarray, prefix: str,
                    x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    xn = (x - mu) * jax.lax.rsqrt(var + eps)
    return xn * spec.get(flat, f"{prefix}.gamma") + spec.get(flat, f"{prefix}.beta")


def declare_linear(spec: ParamSpec, prefix: str, c_in: int, c_out: int) -> None:
    spec.add(f"{prefix}.w", (c_in, c_out), "uniform_fanin", fan_in=c_in)
    spec.add(f"{prefix}.b", (c_out,), "zeros")


def apply_linear(spec: ParamSpec, flat: jnp.ndarray, prefix: str,
                 x: jnp.ndarray) -> jnp.ndarray:
    return x @ spec.get(flat, f"{prefix}.w") + spec.get(flat, f"{prefix}.b")
