"""Flat-parameter packing: one f32 vector crosses the Rust<->XLA boundary.

Every model variant declares an ordered list of named tensors.  The packing
spec assigns each a contiguous slice of a single flat ``f32[P]`` vector; the
offsets are static Python ints, so ``unpack`` lowers to static slices inside
the jitted graph (no gather, no dynamic shapes).

The same spec is serialized into ``artifacts/manifest.json`` and re-parsed by
``rust/src/model/spec.rs``; Rust reproduces the initialization bit-for-bit
(see :mod:`compile.rnginit`), which lets integration tests compare Rust-side
and Python-side numerics on fixed seeds.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from . import rnginit


@dataclass(frozen=True)
class ParamEntry:
    """One named tensor inside the flat parameter vector."""

    name: str
    shape: Tuple[int, ...]
    offset: int
    #: initialization kind: uniform_fanin | zeros | ones | latent | embedding
    init: str
    #: fan-in used by uniform_fanin (ignored otherwise)
    fan_in: int = 0

    @property
    def size(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1


class ParamSpec:
    """Ordered collection of :class:`ParamEntry` with pack/unpack helpers."""

    def __init__(self) -> None:
        self.entries: List[ParamEntry] = []
        self._by_name: Dict[str, ParamEntry] = {}
        self.total: int = 0

    def add(self, name: str, shape: Sequence[int], init: str, fan_in: int = 0) -> ParamEntry:
        if name in self._by_name:
            raise ValueError(f"duplicate parameter name: {name}")
        entry = ParamEntry(name=name, shape=tuple(int(s) for s in shape),
                           offset=self.total, init=init, fan_in=int(fan_in))
        self.entries.append(entry)
        self._by_name[name] = entry
        self.total += entry.size
        return entry

    def __contains__(self, name: str) -> bool:
        return name in self._by_name

    def entry(self, name: str) -> ParamEntry:
        return self._by_name[name]

    # -- pack / unpack ----------------------------------------------------
    def get(self, flat: jnp.ndarray, name: str) -> jnp.ndarray:
        """Static slice of ``flat`` reshaped to the entry's shape."""
        e = self._by_name[name]
        return jnp.reshape(flat[e.offset:e.offset + e.size], e.shape)

    def unpack(self, flat: jnp.ndarray) -> Dict[str, jnp.ndarray]:
        return {e.name: self.get(flat, e.name) for e in self.entries}

    def pack_numpy(self, tree: Dict[str, np.ndarray]) -> np.ndarray:
        out = np.zeros((self.total,), dtype=np.float32)
        for e in self.entries:
            arr = np.asarray(tree[e.name], dtype=np.float32)
            if arr.shape != e.shape:
                raise ValueError(f"{e.name}: expected {e.shape}, got {arr.shape}")
            out[e.offset:e.offset + e.size] = arr.reshape(-1)
        return out

    # -- initialization ----------------------------------------------------
    def init_flat(self, seed: int) -> np.ndarray:
        """Deterministic init of the whole flat vector.

        Mirrored exactly by ``rust/src/model/init.rs``: each element ``j`` of
        entry ``e`` draws ``u = u01(seed, e.offset + j)`` from the SplitMix64
        counter stream and maps it according to ``e.init``.
        """
        out = np.zeros((self.total,), dtype=np.float32)
        for e in self.entries:
            idx = e.offset + np.arange(e.size, dtype=np.uint64)
            if e.init == "zeros":
                vals = np.zeros(e.size, dtype=np.float32)
            elif e.init == "ones":
                vals = np.ones(e.size, dtype=np.float32)
            else:
                u = rnginit.u01(seed, idx)          # f64 in [0,1)
                if e.init == "uniform_fanin":
                    a = 1.0 / math.sqrt(max(e.fan_in, 1))
                    vals = ((2.0 * u - 1.0) * a).astype(np.float32)
                elif e.init == "latent":
                    # latent query tokens: small uniform, paper-style 0.02 scale
                    vals = ((2.0 * u - 1.0) * 0.02).astype(np.float32)
                elif e.init == "embedding":
                    vals = ((2.0 * u - 1.0) * 0.02).astype(np.float32)
                else:
                    raise ValueError(f"unknown init kind {e.init!r}")
            out[e.offset:e.offset + e.size] = vals
        return out

    # -- manifest ----------------------------------------------------------
    def to_manifest(self) -> List[dict]:
        return [
            {
                "name": e.name,
                "shape": list(e.shape),
                "offset": e.offset,
                "size": e.size,
                "init": e.init,
                "fan_in": e.fan_in,
            }
            for e in self.entries
        ]
