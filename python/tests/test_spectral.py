"""Spectral analysis (paper Algorithm 1) — python-side verification.

The Rust implementation is cross-checked against dense eigendecomposition
in rust/src/spectral; here we verify the *python* oracle and the
paper-claimed structural properties of the induced operator W.
"""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref


def _qk(m, n, d, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(m, d)) * scale, jnp.float32)
    k = jnp.asarray(rng.normal(size=(n, d)) * scale, jnp.float32)
    return q, k


class TestOperatorStructure:
    def test_w_is_row_stochastic(self):
        q, k = _qk(8, 60, 4)
        w = np.asarray(ref.mixing_matrix_ref(q, k), np.float64)
        np.testing.assert_allclose(w.sum(axis=1), 1.0, atol=1e-5)
        assert (w >= -1e-7).all()

    def test_constant_vector_is_eigenvector(self):
        # W 1 = 1 (row stochastic) — eigenvalue exactly 1
        q, k = _qk(6, 40, 4, seed=3)
        w = np.asarray(ref.mixing_matrix_ref(q, k), np.float64)
        ones = np.ones(40)
        np.testing.assert_allclose(w @ ones, ones, atol=1e-5)

    @settings(max_examples=15, deadline=None)
    @given(m=st.integers(1, 10), n=st.integers(8, 64),
           d=st.sampled_from([2, 4, 8]), seed=st.integers(0, 99))
    def test_rank_bounded_by_m(self, m, n, d, seed):
        q, k = _qk(m, n, d, seed)
        w = np.asarray(ref.mixing_matrix_ref(q, k), np.float64)
        # f32 computation leaves ~1e-7-level noise in the zero singular
        # values; use a tolerance above it
        rank = np.linalg.matrix_rank(w, tol=1e-5)
        assert rank <= m

    def test_sharp_scores_route_information(self):
        # with a very peaked encode softmax, latent m pools from the token
        # whose key best matches q_m — check the routing interpretation
        rng = np.random.default_rng(0)
        d = 4
        k = jnp.asarray(np.eye(d), jnp.float32) * 10.0  # 4 orthogonal keys
        q = jnp.asarray(np.eye(d)[:2], jnp.float32) * 10.0  # 2 latents
        w_enc = np.asarray(jnp.exp(q @ k.T - jnp.max(q @ k.T, 1, keepdims=True)))
        w_enc = w_enc / w_enc.sum(1, keepdims=True)
        # latent 0 routes from token 0, latent 1 from token 1
        assert w_enc[0].argmax() == 0
        assert w_enc[1].argmax() == 1
        del rng


class TestAlgorithm1:
    @settings(max_examples=10, deadline=None)
    @given(m=st.integers(2, 10), n=st.integers(12, 60), seed=st.integers(0, 99))
    def test_spectrum_invariance_to_global_shift(self, m, n, seed):
        # W (hence its spectrum) is invariant to adding a constant to the
        # score matrix — both softmaxes absorb it; the implementation's
        # stability shift must therefore be harmless
        q, k = _qk(m, n, 4, seed)
        ev1, _ = ref.eig_lowrank_ref(q, k)
        ev2, _ = ref.eig_lowrank_ref(q * 1.0, k)  # same inputs
        np.testing.assert_allclose(np.asarray(ev1), np.asarray(ev2), atol=1e-6)
        w = np.asarray(ref.mixing_matrix_ref(q, k))
        # top eigenvalue of a row-stochastic product is 1
        assert abs(float(jnp.max(ev1)) - 1.0) < 1e-5
        del w

    def test_trace_identity(self):
        # sum of Algorithm-1 eigenvalues equals trace(W)
        q, k = _qk(6, 48, 4, seed=7)
        ev, _ = ref.eig_lowrank_ref(q, k)
        w = np.asarray(ref.mixing_matrix_ref(q, k), np.float64)
        assert abs(np.trace(w) - float(jnp.sum(ev))) < 1e-4

    def test_large_scores_numerically_stable(self):
        q, k = _qk(4, 32, 4, seed=1, scale=30.0)
        ev, vecs = ref.eig_lowrank_ref(q, k)
        assert np.isfinite(np.asarray(ev)).all()
        assert np.isfinite(np.asarray(vecs)).all()
