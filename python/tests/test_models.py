"""L2 model tests: packing, shapes, gradients, train-step behaviour."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import models, train
from compile.models import ModelCfg
from compile.packing import ParamSpec
from compile.train import OptCfg

jax.config.update("jax_platform_name", "cpu")

SMALL = dict(n=64, d_in=2, d_out=1, c=16, heads=2, m=8, blocks=2)


def _x(cfg, batch=2, seed=0):
    rng = np.random.default_rng(seed)
    if cfg.task == "classification":
        return jnp.asarray(rng.integers(0, cfg.vocab, size=(batch, cfg.n)),
                           jnp.int32)
    return jnp.asarray(rng.normal(size=(batch, cfg.n, cfg.d_in)), jnp.float32)


def _y(cfg, batch=2, seed=1):
    rng = np.random.default_rng(seed)
    if cfg.task == "classification":
        return jnp.asarray(rng.integers(0, cfg.num_classes, size=(batch,)),
                           jnp.int32)
    return jnp.asarray(rng.normal(size=(batch, cfg.n, cfg.d_out)), jnp.float32)


class TestPacking:
    def test_offsets_contiguous(self):
        spec = models.build_spec(ModelCfg(**SMALL))
        off = 0
        for e in spec.entries:
            assert e.offset == off
            off += e.size
        assert spec.total == off

    def test_pack_unpack_roundtrip(self):
        spec = models.build_spec(ModelCfg(**SMALL))
        flat = jnp.asarray(spec.init_flat(7))
        tree = spec.unpack(flat)
        repacked = spec.pack_numpy({k: np.asarray(v) for k, v in tree.items()})
        np.testing.assert_array_equal(repacked, np.asarray(flat))

    def test_init_deterministic(self):
        spec = models.build_spec(ModelCfg(**SMALL))
        np.testing.assert_array_equal(spec.init_flat(42), spec.init_flat(42))
        assert not np.array_equal(spec.init_flat(42), spec.init_flat(43))

    def test_init_kinds(self):
        spec = models.build_spec(ModelCfg(**SMALL))
        flat = spec.init_flat(42)
        for e in spec.entries:
            seg = flat[e.offset:e.offset + e.size]
            if e.init == "zeros":
                assert (seg == 0).all()
            elif e.init == "ones":
                assert (seg == 1).all()
            elif e.init == "uniform_fanin":
                a = 1.0 / np.sqrt(max(e.fan_in, 1))
                assert np.abs(seg).max() <= a + 1e-7
                assert seg.std() > 0
            elif e.init == "latent":
                assert np.abs(seg).max() <= 0.02 + 1e-7

    def test_duplicate_name_rejected(self):
        spec = ParamSpec()
        spec.add("a", (2, 2), "zeros")
        with pytest.raises(ValueError):
            spec.add("a", (2,), "zeros")


ALL_MIXERS = ["flare", "vanilla", "linformer", "transolver", "perceiver",
              "lno", "linatt", "performer", "gnot"]


class TestForward:
    @pytest.mark.parametrize("mixer", ALL_MIXERS)
    def test_shapes_regression(self, mixer):
        cfg = ModelCfg(mixer=mixer, **SMALL)
        spec = models.build_spec(cfg)
        flat = jnp.asarray(spec.init_flat(0))
        y = models.forward_batched(cfg, spec, flat, _x(cfg))
        assert y.shape == (2, cfg.n, cfg.d_out)
        assert np.isfinite(np.asarray(y)).all()

    @pytest.mark.parametrize("mixer", ["flare", "vanilla", "linformer"])
    def test_shapes_classification(self, mixer):
        cfg = ModelCfg(mixer=mixer, task="classification", vocab=16,
                       num_classes=5, **SMALL)
        spec = models.build_spec(cfg)
        flat = jnp.asarray(spec.init_flat(0))
        y = models.forward_batched(cfg, spec, flat, _x(cfg, batch=3))
        assert y.shape == (3, 5)

    def test_flare_permutation_equivariance(self):
        cfg = ModelCfg(mixer="flare", **SMALL)
        spec = models.build_spec(cfg)
        flat = jnp.asarray(spec.init_flat(0))
        x = _x(cfg, batch=1)
        perm = np.random.default_rng(0).permutation(cfg.n)
        y = np.asarray(models.forward_batched(cfg, spec, flat, x))
        yp = np.asarray(models.forward_batched(cfg, spec, flat, x[:, perm]))
        np.testing.assert_allclose(yp, y[:, perm], atol=2e-5, rtol=2e-5)

    def test_vanilla_not_equivariant_check_is_meaningful(self):
        # sanity for the test above: outputs actually depend on inputs
        cfg = ModelCfg(mixer="flare", **SMALL)
        spec = models.build_spec(cfg)
        flat = jnp.asarray(spec.init_flat(0))
        y1 = models.forward_batched(cfg, spec, flat, _x(cfg, seed=0))
        y2 = models.forward_batched(cfg, spec, flat, _x(cfg, seed=9))
        assert np.abs(np.asarray(y1 - y2)).max() > 1e-6

    def test_shared_latents_param_shape(self):
        cfg = ModelCfg(mixer="flare", shared_latents=True, **SMALL)
        spec = models.build_spec(cfg)
        e = spec.entry("blk0.mix.latents")
        assert e.shape == (cfg.m, cfg.c // cfg.heads)
        indep = models.build_spec(ModelCfg(mixer="flare", **SMALL))
        assert indep.entry("blk0.mix.latents").shape == \
            (cfg.heads, cfg.m, cfg.c // cfg.heads)
        assert spec.total < indep.total

    def test_hybrid_latent_sa_runs(self):
        cfg = ModelCfg(mixer="flare", latent_sa_blocks=2, **SMALL)
        spec = models.build_spec(cfg)
        flat = jnp.asarray(spec.init_flat(0))
        y = models.forward_batched(cfg, spec, flat, _x(cfg))
        assert y.shape == (2, cfg.n, 1)
        assert np.isfinite(np.asarray(y)).all()

    def test_hybrid_lb0_matches_plain(self):
        # L_B = 0 hybrid path must equal the fused mixer path
        cfg0 = ModelCfg(mixer="flare", **SMALL)
        spec = models.build_spec(cfg0)
        flat = jnp.asarray(spec.init_flat(0))
        x = _x(cfg0)
        y_sdpa = models.forward_batched(cfg0, spec, flat, x)
        cfg_c = dataclasses.replace(cfg0, mixer_impl="chunked")
        y_chunk = models.forward_batched(cfg_c, spec, flat, x)
        np.testing.assert_allclose(np.asarray(y_sdpa), np.asarray(y_chunk),
                                   atol=2e-5, rtol=2e-5)

    def test_param_counts_ordered_like_paper(self):
        # paper Table 1: FLARE uses fewer params than perceiver-style models
        flare = models.param_count(ModelCfg(mixer="flare", **SMALL))
        perceiver = models.param_count(
            ModelCfg(mixer="perceiver", **{**SMALL, "c": 32}))
        assert flare < perceiver

    def test_qk_forward_shapes(self):
        cfg = ModelCfg(mixer="flare", **SMALL)
        spec = models.build_spec(cfg)
        flat = jnp.asarray(spec.init_flat(0))
        x = jnp.asarray(np.random.default_rng(0).normal(
            size=(cfg.n, cfg.d_in)), jnp.float32)
        ks = models.qk_forward(cfg, spec, flat, x)
        assert len(ks) == cfg.blocks
        for k in ks:
            assert k.shape == (cfg.heads, cfg.n, cfg.head_dim)


class TestTrainStep:
    def _setup(self, mixer="flare", task="regression"):
        kw = dict(SMALL)
        if task == "classification":
            cfg = ModelCfg(mixer=mixer, task=task, vocab=16, num_classes=4,
                           **kw)
        else:
            cfg = ModelCfg(mixer=mixer, **kw)
        spec = models.build_spec(cfg)
        step = jax.jit(train.make_train_step(cfg, spec, OptCfg()))
        flat = jnp.asarray(spec.init_flat(3))
        z = jnp.zeros_like(flat)
        return cfg, spec, step, flat, z

    @pytest.mark.parametrize("mixer", ["flare", "vanilla", "transolver"])
    def test_loss_decreases(self, mixer):
        cfg, spec, step, p, z = self._setup(mixer)
        x, y = _x(cfg), _y(cfg)
        m, v = z, z
        losses = []
        for t in range(30):
            p, m, v, loss = step(p, m, v, float(t), 3e-3, x, y)
            losses.append(float(loss))
        assert losses[-1] < losses[0] * 0.9, losses

    def test_loss_decreases_classification(self):
        cfg, spec, step, p, z = self._setup("flare", "classification")
        x, y = _x(cfg, batch=4), _y(cfg, batch=4)
        m, v = z, z
        losses = []
        for t in range(30):
            p, m, v, loss = step(p, m, v, float(t), 3e-3, x, y)
            losses.append(float(loss))
        assert losses[-1] < losses[0]

    def test_grad_clip_bounds_update(self):
        cfg, spec, step, p, z = self._setup()
        x = _x(cfg)
        y = _y(cfg) * 1e6  # huge targets -> huge raw grads
        p2, m, v, loss = step(p, z, z, 0.0, 1e-3, x, y)
        assert np.isfinite(np.asarray(p2)).all()
        # after clip to norm 1, first Adam step magnitude is bounded by
        # lr * (1/sqrt(1e-3 * g^2 / ...)) — just check no explosion:
        assert np.abs(np.asarray(p2 - p)).max() < 1.0

    def test_gradients_match_finite_difference(self):
        cfg = ModelCfg(mixer="flare", n=16, d_in=2, d_out=1, c=8, heads=2,
                       m=4, blocks=1, kv_layers=1, ffn_layers=1, io_layers=1)
        spec = models.build_spec(cfg)
        loss_fn = train.make_loss_fn(cfg, spec)
        flat = jnp.asarray(spec.init_flat(0), jnp.float32)
        x, y = _x(cfg, batch=1), _y(cfg, batch=1)
        g = np.asarray(jax.grad(loss_fn)(flat, x, y), np.float64)
        rng = np.random.default_rng(0)
        idxs = rng.choice(spec.total, size=12, replace=False)
        eps = 1e-3
        for i in idxs:
            fp = np.asarray(flat).copy()
            fm_ = np.asarray(flat).copy()
            fp[i] += eps
            fm_[i] -= eps
            num = (float(loss_fn(jnp.asarray(fp), x, y)) -
                   float(loss_fn(jnp.asarray(fm_), x, y))) / (2 * eps)
            assert abs(num - g[i]) < 5e-3 + 0.05 * abs(num), \
                f"param {i}: fd={num} ad={g[i]}"

    def test_rel_l2_loss_values(self):
        y = jnp.ones((2, 8, 1))
        assert float(train.rel_l2_loss(y, y)) < 1e-6
        assert abs(float(train.rel_l2_loss(jnp.zeros_like(y), y)) - 1.0) < 1e-6

    def test_cross_entropy_uniform(self):
        logits = jnp.zeros((4, 10))
        labels = jnp.asarray([0, 3, 7, 9], jnp.int32)
        assert abs(float(train.cross_entropy_loss(logits, labels)) -
                   np.log(10)) < 1e-5


class TestWeightDecayAndSchedule:
    def test_weight_decay_shrinks_params(self):
        cfg = ModelCfg(mixer="flare", **SMALL)
        spec = models.build_spec(cfg)
        step_wd = jax.jit(train.make_train_step(cfg, spec, OptCfg(weight_decay=0.5)))
        step_no = jax.jit(train.make_train_step(cfg, spec, OptCfg(weight_decay=0.0)))
        p = jnp.asarray(spec.init_flat(3))
        z = jnp.zeros_like(p)
        x, y = _x(cfg), _y(cfg)
        p_wd, *_ = step_wd(p, z, z, 0.0, 1e-2, x, y)
        p_no, *_ = step_no(p, z, z, 0.0, 1e-2, x, y)
        assert float(jnp.sum(p_wd ** 2)) < float(jnp.sum(p_no ** 2))
