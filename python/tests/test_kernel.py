"""L1 correctness: the Pallas FLARE kernel vs the pure-jnp oracle.

This is the core correctness signal of the compile path: every mixer
implementation (pallas two-pass streaming, chunked-scan, dense sdpa) must
agree with the materialized reference from the paper's Figure 7 pseudocode.
Hypothesis sweeps shapes, dtypes-ish ranges, scales and tile sizes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import flare_mixer as fm
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def _rand(shape, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape) * scale, jnp.float32)


def _qkv(h, m, n, d, seed=0, scale=1.0):
    return (_rand((h, m, d), seed, scale),
            _rand((h, n, d), seed + 1, scale),
            _rand((h, n, d), seed + 2, scale))


class TestPallasKernel:
    def test_matches_ref_basic(self):
        q, k, v = _qkv(4, 16, 256, 8)
        y = fm.flare_mixer_pallas(q, k, v, tile=64)
        np.testing.assert_allclose(np.asarray(y),
                                   np.asarray(ref.flare_mixer_ref(q, k, v)),
                                   atol=1e-5, rtol=1e-5)

    def test_ragged_tail_tile(self):
        # N not divisible by tile exercises the in-kernel mask
        q, k, v = _qkv(2, 8, 100, 4)
        y = fm.flare_mixer_pallas(q, k, v, tile=32)
        np.testing.assert_allclose(np.asarray(y),
                                   np.asarray(ref.flare_mixer_ref(q, k, v)),
                                   atol=1e-5, rtol=1e-5)

    def test_single_tile(self):
        q, k, v = _qkv(2, 8, 48, 4)
        y = fm.flare_mixer_pallas(q, k, v, tile=64)  # tile > N
        np.testing.assert_allclose(np.asarray(y),
                                   np.asarray(ref.flare_mixer_ref(q, k, v)),
                                   atol=1e-5, rtol=1e-5)

    def test_one_latent(self):
        # M=1: rank-1 mixing; decode softmax over a single latent == 1
        q, k, v = _qkv(2, 1, 64, 4)
        y = fm.flare_mixer_pallas(q, k, v, tile=32)
        np.testing.assert_allclose(np.asarray(y),
                                   np.asarray(ref.flare_mixer_ref(q, k, v)),
                                   atol=1e-5, rtol=1e-5)

    def test_one_head(self):
        q, k, v = _qkv(1, 8, 64, 16)
        y = fm.flare_mixer_pallas(q, k, v, tile=16)
        np.testing.assert_allclose(np.asarray(y),
                                   np.asarray(ref.flare_mixer_ref(q, k, v)),
                                   atol=1e-5, rtol=1e-5)

    def test_extreme_logits_stable(self):
        # large-magnitude scores: online softmax must not overflow
        q, k, v = _qkv(2, 8, 128, 8, scale=10.0)
        y = fm.flare_mixer_pallas(q, k, v, tile=32)
        assert np.isfinite(np.asarray(y)).all()
        np.testing.assert_allclose(np.asarray(y),
                                   np.asarray(ref.flare_mixer_ref(q, k, v)),
                                   atol=1e-4, rtol=1e-4)

    def test_scale_parameter(self):
        q, k, v = _qkv(2, 8, 96, 8)
        for s in (0.25, 1.0, 2.0):
            y = fm.flare_mixer_pallas(q, k, v, scale=s, tile=32)
            np.testing.assert_allclose(
                np.asarray(y), np.asarray(ref.flare_mixer_ref(q, k, v, s)),
                atol=1e-5, rtol=1e-5)

    @settings(max_examples=25, deadline=None)
    @given(h=st.integers(1, 4), m=st.integers(1, 32),
           n=st.integers(2, 200), d=st.sampled_from([2, 4, 8, 16]),
           tile=st.sampled_from([16, 32, 64, 128]),
           seed=st.integers(0, 1000))
    def test_hypothesis_sweep(self, h, m, n, d, tile, seed):
        q, k, v = _qkv(h, m, n, d, seed=seed)
        y = fm.flare_mixer_pallas(q, k, v, tile=tile)
        np.testing.assert_allclose(np.asarray(y),
                                   np.asarray(ref.flare_mixer_ref(q, k, v)),
                                   atol=2e-5, rtol=2e-5)


class TestChunkedMixer:
    @settings(max_examples=20, deadline=None)
    @given(h=st.integers(1, 4), m=st.integers(1, 16),
           n=st.integers(2, 300), chunk=st.sampled_from([16, 64, 128]),
           seed=st.integers(0, 1000))
    def test_hypothesis_sweep(self, h, m, n, chunk, seed):
        q, k, v = _qkv(h, m, n, 4, seed=seed)
        y = fm.flare_mixer_chunked(q, k, v, chunk=chunk)
        np.testing.assert_allclose(np.asarray(y),
                                   np.asarray(ref.flare_mixer_ref(q, k, v)),
                                   atol=2e-5, rtol=2e-5)

    def test_chunk_larger_than_n(self):
        q, k, v = _qkv(2, 8, 40, 4)
        y = fm.flare_mixer_chunked(q, k, v, chunk=4096)
        np.testing.assert_allclose(np.asarray(y),
                                   np.asarray(ref.flare_mixer_ref(q, k, v)),
                                   atol=1e-5, rtol=1e-5)


class TestSdpaMixer:
    def test_matches_ref(self):
        q, k, v = _qkv(4, 16, 128, 8)
        y = fm.flare_mixer_sdpa(q, k, v)
        np.testing.assert_allclose(np.asarray(y),
                                   np.asarray(ref.flare_mixer_ref(q, k, v)),
                                   atol=1e-5, rtol=1e-5)


class TestMixerMath:
    """Structural invariants of the FLARE operator itself."""

    def test_rank_at_most_m(self):
        q, k, _ = _qkv(1, 4, 64, 8)
        w = np.asarray(ref.mixing_matrix_ref(q[0], k[0]))
        rank = np.linalg.matrix_rank(w, tol=1e-6)
        assert rank <= 4

    def test_rows_sum_to_one(self):
        # W = W_dec W_enc is a product of row-stochastic matrices
        q, k, _ = _qkv(1, 8, 64, 8)
        w = np.asarray(ref.mixing_matrix_ref(q[0], k[0]))
        np.testing.assert_allclose(w.sum(axis=1), np.ones(64), atol=1e-5)
        assert (w >= -1e-7).all()

    def test_permutation_equivariance(self):
        # FLARE is permutation equivariant: mixer(P x) = P mixer(x)
        q, k, v = _qkv(2, 8, 64, 4)
        perm = np.random.default_rng(3).permutation(64)
        y = np.asarray(fm.flare_mixer_sdpa(q, k, v))
        yp = np.asarray(fm.flare_mixer_sdpa(q, k[:, perm], v[:, perm]))
        np.testing.assert_allclose(yp, y[:, perm], atol=1e-5, rtol=1e-5)

    def test_constant_value_fixed_point(self):
        # if V is constant across tokens, Y equals that constant
        q, k, _ = _qkv(2, 8, 64, 4)
        v = jnp.ones((2, 64, 4)) * 3.5
        y = np.asarray(fm.flare_mixer_sdpa(q, k, v))
        np.testing.assert_allclose(y, 3.5 * np.ones_like(y), atol=1e-5)


class TestEigLowRank:
    """Paper Algorithm 1 vs dense eigendecomposition."""

    @settings(max_examples=10, deadline=None)
    @given(m=st.integers(2, 12), n=st.integers(16, 80),
           seed=st.integers(0, 100))
    def test_eigenvalues_match_dense(self, m, n, seed):
        q = _rand((m, 8), seed)
        k = _rand((n, 8), seed + 1)
        evals, _ = ref.eig_lowrank_ref(q, k)
        w = np.asarray(ref.mixing_matrix_ref(q, k), np.float64)
        dense = np.sort(np.abs(np.linalg.eigvals(w)))[::-1][:m]
        np.testing.assert_allclose(np.sort(np.asarray(evals))[::-1], dense,
                                   atol=1e-4, rtol=1e-3)

    def test_eigenvectors_satisfy_definition(self):
        q = _rand((6, 8), 0)
        k = _rand((40, 8), 1)
        evals, vecs = ref.eig_lowrank_ref(q, k)
        w = np.asarray(ref.mixing_matrix_ref(q, k), np.float64)
        v = np.asarray(vecs, np.float64)
        lam = np.asarray(evals, np.float64)
        np.testing.assert_allclose(w @ v, v * lam[None, :], atol=1e-4)

    def test_spectrum_bounded_by_one(self):
        # W is a product of row-stochastic matrices: spectral radius <= 1
        q = _rand((8, 4), 5)
        k = _rand((50, 4), 6)
        evals, _ = ref.eig_lowrank_ref(q, k)
        assert np.asarray(evals).max() <= 1.0 + 1e-5
