"""AOT pipeline tests: manifest consistency and HLO round-trip shape."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, cases, models, train
from compile.cases import DATASETS
from compile.models import ModelCfg
from compile.train import OptCfg

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


class TestCaseTable:
    def test_unique_names(self):
        cs = cases.build_cases()
        names = [c.name for c in cs]
        assert len(names) == len(set(names))

    def test_every_case_dataset_exists(self):
        for c in cases.build_cases():
            assert c.dataset in DATASETS

    def test_groups_known(self):
        for c in cases.build_cases():
            assert c.group in cases.GROUPS

    def test_classification_cases_have_vocab(self):
        for c in cases.build_cases():
            if c.model.task == "classification":
                assert c.model.vocab > 1
                assert c.model.num_classes > 1

    def test_table1_covers_models_and_datasets(self):
        t1 = [c for c in cases.build_cases() if c.group == "table1"]
        mixers = {c.model.mixer for c in t1}
        assert mixers == set(cases.TABLE1_MODELS)
        dsets = {c.dataset for c in t1}
        assert dsets == set(cases.PDE_SETS)

    def test_table2_covers_lra(self):
        t2 = [c for c in cases.build_cases() if c.group == "table2"]
        assert {c.dataset for c in t2} == set(cases.LRA_TASKS)
        assert {c.model.mixer for c in t2} == set(cases.TABLE2_MODELS)

    def test_fig12_has_shared_and_indep(self):
        f12 = [c for c in cases.build_cases() if c.group == "fig12"]
        assert any(c.model.shared_latents for c in f12)
        assert any(not c.model.shared_latents for c in f12)
        assert all("qk" in c.kinds for c in f12)


class TestHloText:
    def test_lowering_produces_parseable_hlo(self):
        cfg = ModelCfg(n=32, d_in=2, d_out=1, c=8, heads=2, m=4, blocks=1)
        spec = models.build_spec(cfg)
        fwd = train.make_forward_fn(cfg, spec)
        lowered = jax.jit(fwd).lower(
            jax.ShapeDtypeStruct((spec.total,), jnp.float32),
            jax.ShapeDtypeStruct((1, 32, 2), jnp.float32))
        text = aot.to_hlo_text(lowered)
        assert "HloModule" in text
        assert "ENTRY" in text

    def test_hlo_text_reparses(self):
        # the text must round-trip through XLA's HLO parser — this is the
        # exact path the Rust runtime uses (HloModuleProto::from_text_file);
        # end-to-end numerics vs python are covered by rust/tests/.
        from jax._src.lib import xla_client as xc
        cfg = ModelCfg(n=16, d_in=2, d_out=1, c=8, heads=2, m=4, blocks=1)
        spec = models.build_spec(cfg)
        fwd = train.make_forward_fn(cfg, spec)
        lowered = jax.jit(fwd).lower(
            jax.ShapeDtypeStruct((spec.total,), jnp.float32),
            jax.ShapeDtypeStruct((1, 16, 2), jnp.float32))
        text = aot.to_hlo_text(lowered)
        mod = xc._xla.hlo_module_from_text(text)
        assert mod is not None


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="artifacts not built")
class TestManifest:
    @pytest.fixture(scope="class")
    def manifest(self):
        with open(os.path.join(ART, "manifest.json")) as f:
            return json.load(f)

    def test_every_artifact_file_exists(self, manifest):
        for case in manifest["cases"]:
            for kind, fname in case["artifacts"].items():
                assert os.path.exists(os.path.join(ART, fname)), fname
        for m in manifest["mixers"] + manifest["layers"]:
            assert os.path.exists(os.path.join(ART, m["file"]))

    def test_param_counts_match_spec(self, manifest):
        for case in manifest["cases"][:10]:
            cfg = ModelCfg(**case["model"])
            assert models.build_spec(cfg).total == case["param_count"]

    def test_param_entries_cover_vector(self, manifest):
        for case in manifest["cases"][:10]:
            total = case["param_count"]
            covered = sum(e["size"] for e in case["params"])
            assert covered == total
            offs = sorted(e["offset"] for e in case["params"])
            assert offs[0] == 0
