//! Table 2 reproduction: accuracy (%) on LRA-style long-context tasks for
//! FLARE vs efficient-attention baselines (vanilla, linear attention,
//! Linformer, Performer).
//!
//! CPU scaling: generator-based tasks (exact labels), N=512-1024, small
//! models, 150 steps.  The claim under test: FLARE is competitive with and
//! on average better than the general-purpose efficient-attention methods.
//!
//! Run: cargo bench --bench table2_lra

use std::collections::BTreeMap;

use flare::bench::{save_results, sweep_steps, train_measurement, Table};
use flare::config::Manifest;
use flare::runtime::default_backend;

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load(Manifest::default_dir())?;
    let steps = sweep_steps(150);
    let cases = manifest.cases_in_group("table2");
    anyhow::ensure!(!cases.is_empty(), "table2 artifacts missing");

    println!("=== Table 2: LRA-style accuracy %% (steps = {steps}) ===\n");
    let mut results: BTreeMap<String, BTreeMap<String, f64>> = BTreeMap::new();
    let mut all = Vec::new();
    let total = cases.len();
    for (i, case) in cases.iter().enumerate() {
        let backend = default_backend()?;
        eprintln!("[{}/{total}] {}", i + 1, case.name);
        let m = train_measurement(backend.as_ref(), &manifest, case, steps)?;
        results
            .entry(case.model.mixer.clone())
            .or_default()
            .insert(case.dataset.clone(), m.extra("accuracy").unwrap_or(0.0));
        all.push(m);
    }

    let tasks = ["listops", "text", "retrieval", "image", "pathfinder"];
    let mut table = Table::new(&[
        "model", "listops", "text", "retrieval", "image", "pathfinder", "avg",
    ]);
    let mut avgs: BTreeMap<String, f64> = BTreeMap::new();
    for (model, per) in &results {
        let mut row = vec![model.clone()];
        let mut sum = 0.0;
        for t in &tasks {
            let acc = per.get(*t).copied().unwrap_or(0.0) * 100.0;
            row.push(format!("{acc:.1}"));
            sum += acc;
        }
        let avg = sum / tasks.len() as f64;
        avgs.insert(model.clone(), avg);
        row.push(format!("{avg:.1}"));
        table.row(row);
    }
    table.print();

    let flare_avg = avgs.get("flare").copied().unwrap_or(0.0);
    let best_other = avgs
        .iter()
        .filter(|(m, _)| m.as_str() != "flare")
        .map(|(_, v)| *v)
        .fold(0.0, f64::max);
    println!(
        "\nFLARE avg {flare_avg:.1} vs best baseline {best_other:.1} \
         (paper: FLARE highest average)"
    );
    let path = save_results("table2_lra", &all)?;
    println!("results written to {path:?}");
    Ok(())
}
