//! Figure 13 reproduction: effect of head dimension D (with total width C
//! fixed) on Elasticity test error.
//!
//! Paper claim: FLARE works best with MANY SMALL heads (D = 4-8) — the
//! reverse of vanilla-transformer practice — because each head is an
//! independent low-rank projection-reconstruction pathway and more parallel
//! pathways approximate richer attention than fewer, wider ones.
//!
//! Run: cargo bench --bench fig13_head_dim

use flare::bench::{save_results, sweep_steps, train_measurement, Table};
use flare::config::Manifest;
use flare::runtime::default_backend;

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load(Manifest::default_dir())?;
    let steps = sweep_steps(150);
    let mut cases = manifest.cases_in_group("fig13");
    anyhow::ensure!(!cases.is_empty(), "fig13 artifacts missing");
    cases.sort_by_key(|c| c.model.heads);

    println!("=== Figure 13: head dimension sweep, steps = {steps} ===\n");
    let mut all = Vec::new();
    let mut table = Table::new(&["heads H", "head dim D", "rel-L2", "params"]);
    for case in &cases {
        let backend = default_backend()?;
        eprintln!("running {}", case.name);
        let mut m = train_measurement(backend.as_ref(), &manifest, case, steps)?;
        m.extras.push(("head_dim".into(), case.model.head_dim() as f64));
        table.row(vec![
            case.model.heads.to_string(),
            case.model.head_dim().to_string(),
            format!("{:.4}", m.extra("rel_l2").unwrap_or(f64::NAN)),
            format!("{}k", case.param_count / 1000),
        ]);
        all.push(m);
    }
    table.print();

    let best = all
        .iter()
        .min_by(|a, b| {
            a.extra("rel_l2")
                .unwrap_or(f64::INFINITY)
                .partial_cmp(&b.extra("rel_l2").unwrap_or(f64::INFINITY))
                .unwrap()
        })
        .unwrap();
    println!(
        "\nbest head dim: D={} (paper: D in {{4, 8}} optimal)",
        best.extra("head_dim").unwrap_or(f64::NAN)
    );
    let path = save_results("fig13_head_dim", &all)?;
    println!("results written to {path:?}");
    Ok(())
}
