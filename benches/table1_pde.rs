//! Table 1 reproduction: relative L2 error (x1e-3 in the paper) and
//! parameter counts across PDE benchmarks for FLARE and every baseline.
//!
//! CPU scaling: simulator datasets, C=32/B=2 models, 200 training steps
//! (the paper: real datasets, C=64/B=8, 500 epochs on GPUs).  The claim
//! under test is the *ordering* — FLARE at or near the best error with the
//! smallest parameter count — not absolute values.
//!
//! Run: cargo bench --bench table1_pde     (FLARE_BENCH_QUICK=1 to smoke)

use std::collections::BTreeMap;

use flare::bench::{save_results, sweep_steps, train_measurement, Table};
use flare::config::Manifest;
use flare::runtime::default_backend;

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load(Manifest::default_dir())?;
    let steps = sweep_steps(200);
    let cases = manifest.cases_in_group("table1");
    anyhow::ensure!(!cases.is_empty(), "table1 artifacts missing");

    println!("=== Table 1: PDE surrogate rel-L2 (steps = {steps}) ===\n");
    // results[model][dataset] = (rel_l2, params)
    let mut results: BTreeMap<String, BTreeMap<String, (f64, usize)>> = BTreeMap::new();
    let mut all = Vec::new();
    let total = cases.len();
    for (i, case) in cases.iter().enumerate() {
        let backend = default_backend()?; // fresh backend per case bounds memory
        eprintln!("[{}/{total}] {}", i + 1, case.name);
        let m = train_measurement(backend.as_ref(), &manifest, case, steps)?;
        results
            .entry(case.model.mixer.clone())
            .or_default()
            .insert(
                case.dataset.clone(),
                (m.extra("rel_l2").unwrap_or(f64::NAN), case.param_count),
            );
        all.push(m);
    }

    let datasets = ["elasticity", "darcy", "airfoil", "pipe", "drivaer", "lpbf"];
    let mut table = Table::new(&[
        "model", "elasticity", "darcy", "airfoil", "pipe", "drivaer", "lpbf", "params",
    ]);
    for (model, per_ds) in &results {
        let mut row = vec![model.clone()];
        for ds in &datasets {
            row.push(
                per_ds
                    .get(*ds)
                    .map(|(e, _)| format!("{:.4}", e))
                    .unwrap_or_else(|| "~".into()),
            );
        }
        let params = per_ds.values().next().map(|(_, p)| *p).unwrap_or(0);
        row.push(format!("{}k", params / 1000));
        table.row(row);
    }
    table.print();

    // headline check: FLARE wins (or ties) most datasets
    let flare = &results["flare"];
    let mut wins = 0;
    for ds in &datasets {
        let Some((fe, _)) = flare.get(*ds) else { continue };
        let best_other = results
            .iter()
            .filter(|(m, _)| m.as_str() != "flare")
            .filter_map(|(_, per)| per.get(*ds).map(|(e, _)| *e))
            .fold(f64::INFINITY, f64::min);
        if *fe <= best_other * 1.05 {
            wins += 1;
        }
    }
    println!("\nFLARE best-or-within-5% on {wins}/{} datasets", datasets.len());
    let path = save_results("table1_pde", &all)?;
    println!("results written to {path:?}");
    Ok(())
}
