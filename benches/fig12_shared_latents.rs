//! Figure 12 reproduction: shared vs independent per-head latent tokens —
//! eigenvalue spectra of the head-specific mixing operators W_h plus the
//! test-error table.
//!
//! Paper claims: with shared latents all heads exhibit nearly identical
//! spectra (diversity collapses) and error is higher; independent latent
//! slices yield visibly different decay profiles per head and lower error.
//!
//! Run: cargo bench --bench fig12_shared_latents

use flare::bench::{save_results, sweep_steps, Measurement, Table};
use flare::config::Manifest;
use flare::data;
use flare::model::{find_entry, param_slice};
use flare::runtime::default_backend;
use flare::spectral::{eig_lowrank, spectra_diversity, HeadSpectrum};
use flare::train::{train_case, TrainOpts};
use flare::util::stats::Summary;

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load(Manifest::default_dir())?;
    let steps = sweep_steps(200);
    let cases = manifest.cases_in_group("fig12");
    anyhow::ensure!(!cases.is_empty(), "fig12 artifacts missing");

    println!("=== Figure 12: shared vs independent latents, steps = {steps} ===\n");
    let mut all: Vec<Measurement> = Vec::new();
    let mut table = Table::new(&["B", "latents", "rel-L2", "params", "spectral diversity"]);

    for case in &cases {
        let backend = default_backend()?;
        eprintln!("running {}", case.name);
        let out = train_case(
            backend.as_ref(),
            &manifest,
            case,
            &TrainOpts {
                steps: Some(steps),
                ..Default::default()
            },
        )?;

        // spectra of every head in every block at a test sample
        let ds = data::build(&case.dataset, &case.dataset_meta, manifest.seed)?;
        let ks = backend.qk_keys(&manifest, case, &out.params, &ds.test_fields[0].x)?;
        let (h, m, d, n) = (
            case.model.heads,
            case.model.m,
            case.model.head_dim(),
            case.model.n,
        );
        let mut diversities = Vec::new();
        for (b, kvals) in ks.iter().enumerate() {
            let latents = find_entry(&case.params, &format!("blk{b}.mix.latents"))?;
            let q_all = param_slice(&out.params, latents);
            let spectra: Vec<HeadSpectrum> = (0..h)
                .map(|head| {
                    let q = if case.model.shared_latents {
                        q_all.to_vec()
                    } else {
                        q_all[head * m * d..(head + 1) * m * d].to_vec()
                    };
                    let eig = eig_lowrank(&q, &kvals[head * n * d..(head + 1) * n * d], m, n, d);
                    HeadSpectrum {
                        block: b,
                        head,
                        eigenvalues: eig.eigenvalues,
                    }
                })
                .collect();
            diversities.push(spectra_diversity(&spectra));
        }
        let div = diversities.iter().sum::<f64>() / diversities.len() as f64;
        let tag = if case.model.shared_latents { "shared" } else { "independent" };
        table.row(vec![
            case.model.blocks.to_string(),
            tag.into(),
            format!("{:.4}", out.final_metric),
            format!("{}k", case.param_count / 1000),
            format!("{div:.4}"),
        ]);
        all.push(Measurement {
            name: case.name.clone(),
            iters: out.steps,
            total_s: out.wall_s,
            per_iter: Summary::of(&[out.step_ms.mean]),
            extras: vec![
                ("rel_l2".into(), out.final_metric),
                ("diversity".into(), div),
                (
                    "shared".into(),
                    if case.model.shared_latents { 1.0 } else { 0.0 },
                ),
                ("blocks".into(), case.model.blocks as f64),
            ],
        });
    }
    table.print();

    // claim check per depth: independent beats shared AND has higher diversity
    for b in [2.0, 4.0] {
        let get = |shared: f64, key: &str| {
            all.iter()
                .find(|x| x.extra("blocks") == Some(b) && x.extra("shared") == Some(shared))
                .and_then(|x| x.extra(key))
        };
        if let (Some(es), Some(ei), Some(ds_), Some(di)) = (
            get(1.0, "rel_l2"),
            get(0.0, "rel_l2"),
            get(1.0, "diversity"),
            get(0.0, "diversity"),
        ) {
            println!(
                "B={b}: error shared {es:.4} vs indep {ei:.4}; \
                 diversity shared {ds_:.4} vs indep {di:.4}"
            );
        }
    }
    let path = save_results("fig12_shared_latents", &all)?;
    println!("results written to {path:?}");
    Ok(())
}
