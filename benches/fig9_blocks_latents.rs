//! Figure 9 reproduction: test rel-L2 vs number of FLARE blocks (B) and
//! latent tokens (M) on the Elasticity and Darcy benchmarks.
//!
//! Paper claims: error falls consistently with B on both problems;
//! Elasticity saturates quickly in M (inherently low-rank) while Darcy
//! keeps improving with M (rank-limited).
//!
//! Run: cargo bench --bench fig9_blocks_latents

use std::collections::BTreeMap;

use flare::bench::{save_results, sweep_steps, train_measurement, Table};
use flare::config::Manifest;
use flare::runtime::default_backend;

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load(Manifest::default_dir())?;
    let steps = sweep_steps(150);
    let cases = manifest.cases_in_group("fig9");
    anyhow::ensure!(!cases.is_empty(), "fig9 artifacts missing");

    println!("=== Figure 9: rel-L2 vs (B, M), steps = {steps} ===\n");
    let mut all = Vec::new();
    // results[dataset][(B, M)] = rel_l2
    let mut grid: BTreeMap<String, BTreeMap<(usize, usize), f64>> = BTreeMap::new();
    let total = cases.len();
    for (i, case) in cases.iter().enumerate() {
        let backend = default_backend()?;
        eprintln!("[{}/{total}] {}", i + 1, case.name);
        let m = train_measurement(backend.as_ref(), &manifest, case, steps)?;
        grid.entry(case.dataset.clone()).or_default().insert(
            (case.model.blocks, case.model.m),
            m.extra("rel_l2").unwrap_or(f64::NAN),
        );
        all.push(m);
    }

    for (ds, per) in &grid {
        println!("\n{ds}:");
        let ms: Vec<usize> = {
            let mut v: Vec<usize> = per.keys().map(|(_, m)| *m).collect();
            v.sort_unstable();
            v.dedup();
            v
        };
        let bs: Vec<usize> = {
            let mut v: Vec<usize> = per.keys().map(|(b, _)| *b).collect();
            v.sort_unstable();
            v.dedup();
            v
        };
        let mut headers: Vec<String> = vec!["B \\ M".into()];
        headers.extend(ms.iter().map(|m| m.to_string()));
        let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        let mut table = Table::new(&hdr_refs);
        for b in &bs {
            let mut row = vec![b.to_string()];
            for m in &ms {
                row.push(
                    per.get(&(*b, *m))
                        .map(|e| format!("{e:.4}"))
                        .unwrap_or_default(),
                );
            }
            table.row(row);
        }
        table.print();
        // trend: deepest model at max M should beat shallowest at max M
        let mmax = *ms.last().unwrap();
        if let (Some(e_shallow), Some(e_deep)) =
            (per.get(&(bs[0], mmax)), per.get(&(*bs.last().unwrap(), mmax)))
        {
            println!(
                "  depth effect at M={mmax}: B={} err {e_shallow:.4} -> B={} err {e_deep:.4}",
                bs[0],
                bs.last().unwrap()
            );
        }
    }
    let path = save_results("fig9_blocks_latents", &all)?;
    println!("\nresults written to {path:?}");
    Ok(())
}
