//! Figure 10 reproduction: effect of ResMLP depth in (left) the key/value
//! projections and (right) the per-block feedforward on Elasticity error.
//!
//! Paper claim: deeper residual K/V projections matter because FLARE's
//! latent queries are input-independent — expressivity must come from the
//! key/value side; deeper FFN helps mildly.
//!
//! Run: cargo bench --bench fig10_resmlp_depth

use flare::bench::{save_results, sweep_steps, train_measurement, Table};
use flare::config::Manifest;
use flare::runtime::default_backend;

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load(Manifest::default_dir())?;
    let steps = sweep_steps(150);
    let cases = manifest.cases_in_group("fig10");
    anyhow::ensure!(!cases.is_empty(), "fig10 artifacts missing");

    println!("=== Figure 10: ResMLP depth ablations, steps = {steps} ===\n");
    let mut all = Vec::new();
    let mut kv_rows = Vec::new();
    let mut ffn_rows = Vec::new();
    let total = cases.len();
    for (i, case) in cases.iter().enumerate() {
        let backend = default_backend()?;
        eprintln!("[{}/{total}] {}", i + 1, case.name);
        let m = train_measurement(backend.as_ref(), &manifest, case, steps)?;
        let err = m.extra("rel_l2").unwrap_or(f64::NAN);
        if case.name.contains("kv") {
            kv_rows.push((case.model.kv_layers, err, case.param_count));
        } else {
            ffn_rows.push((case.model.ffn_layers, err, case.param_count));
        }
        all.push(m);
    }
    kv_rows.sort_by_key(|r| r.0);
    ffn_rows.sort_by_key(|r| r.0);

    println!("\n(left) K/V projection depth:");
    let mut t = Table::new(&["kv layers", "rel-L2", "params"]);
    for (l, e, p) in &kv_rows {
        t.row(vec![l.to_string(), format!("{e:.4}"), format!("{}k", p / 1000)]);
    }
    t.print();

    println!("\n(right) feedforward block depth:");
    let mut t = Table::new(&["ffn layers", "rel-L2", "params"]);
    for (l, e, p) in &ffn_rows {
        t.row(vec![l.to_string(), format!("{e:.4}"), format!("{}k", p / 1000)]);
    }
    t.print();

    if let (Some(first), Some(last)) = (kv_rows.first(), kv_rows.last()) {
        println!(
            "\nK/V depth {} -> {}: rel-L2 {:.4} -> {:.4} ({})",
            first.0,
            last.0,
            first.1,
            last.1,
            if last.1 < first.1 { "deeper is better, as in paper" } else { "flat at this budget" }
        );
    }
    let path = save_results("fig10_resmlp_depth", &all)?;
    println!("results written to {path:?}");
    Ok(())
}
