//! Figure 2 reproduction: time and memory of a forward token-mixing pass vs
//! sequence length, vanilla attention vs FLARE (M in {64, 256}).
//!
//! The paper's claim: vanilla is O(N^2) and blows past practical budgets by
//! N ~ 1e5 while FLARE stays O(NM) with near-flat memory, reaching 1e6
//! tokens; the FLARE curves for different M nearly overlap.  On CPU the
//! absolute times differ from an H100 but the slopes and the crossover
//! survive.
//!
//! Run: cargo bench --bench fig2_scaling

use flare::bench::{quick_mode, save_results, Bench, Measurement, Table};
use flare::config::Manifest;
use flare::runtime::literal::lit_f32;
use flare::runtime::Runtime;
use flare::util::rng::Rng;
use flare::util::stats::current_rss_bytes;

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load(Manifest::default_dir())?;
    anyhow::ensure!(!manifest.mixers.is_empty(), "fig2 artifacts missing");
    let max_n = if quick_mode() { 16384 } else { 1_048_576 };

    println!("=== Figure 2: mixer forward time/memory vs N ===\n");
    let mut all: Vec<Measurement> = Vec::new();
    let mut table = Table::new(&["mixer", "N", "M", "ms/fwd", "MB delta", "ns/token"]);

    for mx in &manifest.mixers {
        if mx.n > max_n {
            continue;
        }
        let rt = Runtime::cpu()?;
        let exe = rt.load(&mx.name, manifest.dir.join(&mx.file))?;
        let (h, d, n, m) = (mx.heads, mx.head_dim, mx.n, mx.m);
        let mut rng = Rng::new(7);
        let mut fill = |len: usize| -> Vec<f32> {
            (0..len).map(|_| rng.normal() as f32).collect()
        };
        let args = if mx.kind == "vanilla_sdpa" {
            vec![
                lit_f32(&fill(h * n * d), &[h as i64, n as i64, d as i64])?,
                lit_f32(&fill(h * n * d), &[h as i64, n as i64, d as i64])?,
                lit_f32(&fill(h * n * d), &[h as i64, n as i64, d as i64])?,
            ]
        } else {
            vec![
                lit_f32(&fill(h * m * d), &[h as i64, m as i64, d as i64])?,
                lit_f32(&fill(h * n * d), &[h as i64, n as i64, d as i64])?,
                lit_f32(&fill(h * n * d), &[h as i64, n as i64, d as i64])?,
            ]
        };

        let rss_before = current_rss_bytes().unwrap_or(0);
        let bench = if quick_mode() { Bench::quick() } else { Bench::default() };
        let mut meas = bench.run(&mx.name, || {
            let _ = rt.run_ref(&exe, &args.iter().collect::<Vec<_>>()).unwrap();
        });
        let rss_after = current_rss_bytes().unwrap_or(rss_before);
        let mb = (rss_after.saturating_sub(rss_before)) as f64 / 1e6;
        meas.extras.push(("n".into(), n as f64));
        meas.extras.push(("m".into(), m as f64));
        meas.extras.push(("rss_delta_mb".into(), mb));
        table.row(vec![
            mx.kind.clone(),
            n.to_string(),
            if m > 0 { m.to_string() } else { "-".into() },
            format!("{:.2}", meas.mean_ms()),
            format!("{mb:.0}"),
            format!("{:.1}", meas.mean_ms() * 1e6 / n as f64),
        ]);
        all.push(meas);
    }
    table.print();

    // slope check: vanilla should scale ~quadratically, FLARE ~linearly
    let slope = |kind: &str| -> Option<f64> {
        let pts: Vec<(f64, f64)> = all
            .iter()
            .filter(|m| m.name.contains(kind))
            // hold M fixed (64) so the slope isolates the N dependence
            .filter(|m| m.extra("m").map(|v| v == 64.0 || v == 0.0).unwrap_or(true))
            .filter_map(|m| Some((m.extra("n")?, m.mean_ms())))
            .collect();
        if pts.len() < 2 {
            return None;
        }
        let (n0, t0) = pts[0];
        let (n1, t1) = pts[pts.len() - 1];
        Some((t1 / t0).ln() / (n1 / n0).ln())
    };
    if let (Some(sv), Some(sf)) = (slope("vanilla"), slope("flare")) {
        println!(
            "\nlog-log slope: vanilla {sv:.2} (paper: ~2), FLARE {sf:.2} (paper: ~1)"
        );
    }
    let path = save_results("fig2_scaling", &all)?;
    println!("results written to {path:?}");
    Ok(())
}
