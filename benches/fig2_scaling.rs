//! Figure 2 reproduction: time and memory of a forward token-mixing pass vs
//! sequence length, naive O(N^2) attention vs the native FLARE mixer
//! (M in {64, 256}).
//!
//! The paper's claim: vanilla is O(N^2) and blows past practical budgets by
//! N ~ 1e5 while FLARE stays O(NM) with near-flat memory, reaching 1e6
//! tokens; the FLARE curves for different M nearly overlap.  This bench
//! exercises the pure-Rust kernels directly (no artifacts needed), so the
//! absolute times differ from an H100 but the slopes and the crossover
//! survive.
//!
//! Run: cargo bench --bench fig2_scaling     (FLARE_BENCH_QUICK=1 to smoke)

use flare::bench::{quick_mode, save_results, Bench, Measurement, Table};
use flare::linalg::matrix::{axpy_f32, dot_f32};
use flare::model::forward::{flare_mixer, mixer_decode, mixer_encode};
use flare::util::rng::Rng;
use flare::util::stats::current_rss_bytes;

/// Dense multi-head softmax attention, O(N^2) time but O(N) extra memory
/// (row-streamed so the bench measures compute scaling, not a score-matrix
/// allocation cliff).
fn naive_attention(q: &[f32], k: &[f32], v: &[f32], h: usize, n: usize, d: usize) -> Vec<f32> {
    let scale = 1.0 / (d as f32).sqrt();
    let mut y = vec![0.0f32; h * n * d];
    let mut row = vec![0.0f32; n];
    for hh in 0..h {
        let base = hh * n * d;
        for i in 0..n {
            let qi = &q[base + i * d..base + (i + 1) * d];
            let mut mx = f32::NEG_INFINITY;
            for (j, rv) in row.iter_mut().enumerate() {
                let s = scale * dot_f32(qi, &k[base + j * d..base + (j + 1) * d]);
                *rv = s;
                mx = mx.max(s);
            }
            let mut den = 0.0f32;
            for rv in row.iter_mut() {
                *rv = (*rv - mx).exp();
                den += *rv;
            }
            let inv = 1.0 / den;
            let yi = &mut y[base + i * d..base + (i + 1) * d];
            for (j, &rv) in row.iter().enumerate() {
                axpy_f32(rv * inv, &v[base + j * d..base + (j + 1) * d], yi);
            }
        }
    }
    y
}

fn fill(rng: &mut Rng, len: usize) -> Vec<f32> {
    (0..len).map(|_| rng.normal() as f32).collect()
}

fn main() -> anyhow::Result<()> {
    let (h, d) = (8usize, 8usize);
    let max_n_flare = if quick_mode() { 16_384 } else { 1_048_576 };
    let max_n_vanilla = if quick_mode() { 2_048 } else { 8_192 };
    let ns = [1_024usize, 2_048, 4_096, 8_192, 16_384, 65_536, 262_144, 1_048_576];

    println!("=== Figure 2: mixer forward time/memory vs N (native kernels) ===\n");
    let mut all: Vec<Measurement> = Vec::new();
    let mut table = Table::new(&["mixer", "N", "M", "ms/fwd", "MB delta", "ns/token"]);
    let bench = if quick_mode() { Bench::quick() } else { Bench::default() };
    let mut rng = Rng::new(7);

    for &n in &ns {
        if n > max_n_flare && n > max_n_vanilla {
            continue;
        }
        let k = fill(&mut rng, h * n * d);
        let v = fill(&mut rng, h * n * d);

        if n <= max_n_vanilla {
            let q = fill(&mut rng, h * n * d);
            let rss_before = current_rss_bytes().unwrap_or(0);
            let mut meas = bench.run(&format!("vanilla_n{n}"), || {
                let _ = naive_attention(&q, &k, &v, h, n, d);
            });
            let rss_after = current_rss_bytes().unwrap_or(rss_before);
            let mb = (rss_after.saturating_sub(rss_before)) as f64 / 1e6;
            meas.extras.push(("n".into(), n as f64));
            meas.extras.push(("m".into(), 0.0));
            meas.extras.push(("rss_delta_mb".into(), mb));
            table.row(vec![
                "vanilla".into(),
                n.to_string(),
                "-".into(),
                format!("{:.2}", meas.mean_ms()),
                format!("{mb:.0}"),
                format!("{:.1}", meas.mean_ms() * 1e6 / n as f64),
            ]);
            all.push(meas);
        }

        for m in [64usize, 256] {
            if n > max_n_flare {
                continue;
            }
            let q = fill(&mut rng, h * m * d);
            let rss_before = current_rss_bytes().unwrap_or(0);
            let mut meas = bench.run(&format!("flare_n{n}_m{m}"), || {
                let _ = flare_mixer(&q, &k, &v, h, m, n, d, 1.0);
            });
            let rss_after = current_rss_bytes().unwrap_or(rss_before);
            let mb = (rss_after.saturating_sub(rss_before)) as f64 / 1e6;
            meas.extras.push(("n".into(), n as f64));
            meas.extras.push(("m".into(), m as f64));
            meas.extras.push(("rss_delta_mb".into(), mb));
            table.row(vec![
                "flare".into(),
                n.to_string(),
                m.to_string(),
                format!("{:.2}", meas.mean_ms()),
                format!("{mb:.0}"),
                format!("{:.1}", meas.mean_ms() * 1e6 / n as f64),
            ]);
            all.push(meas);
        }
    }
    table.print();

    // kernel-level: the tiled encode/decode passes of one head in isolation
    // (fixed N, M) so BENCH_native.json pins where mixer time goes
    {
        let (n, m) = (8_192usize, 64usize);
        let q = fill(&mut rng, m * d);
        let k = fill(&mut rng, n * d);
        let v = fill(&mut rng, n * d);
        let mut mrun = vec![0.0f32; m];
        let mut den = vec![0.0f32; m];
        let mut z = vec![0.0f32; m * d];
        let mut meas = bench.run(&format!("mixer_encode_n{n}_m{m}"), || {
            mixer_encode(&q, &k, &v, m, n, d, 1.0, &mut mrun, &mut den, &mut z);
        });
        meas.extras.push(("n".into(), n as f64));
        meas.extras.push(("m".into(), m as f64));
        all.push(meas);
        let mut y = vec![0.0f32; n * d];
        let mut meas = bench.run(&format!("mixer_decode_n{n}_m{m}"), || {
            y.fill(0.0);
            mixer_decode(&q, &k, &z, m, n, d, 1.0, &mut y);
        });
        meas.extras.push(("n".into(), n as f64));
        meas.extras.push(("m".into(), m as f64));
        all.push(meas);
    }

    // slope check: vanilla should scale ~quadratically, FLARE ~linearly
    let slope = |kind: &str| -> Option<f64> {
        let pts: Vec<(f64, f64)> = all
            .iter()
            .filter(|m| m.name.starts_with(kind))
            // hold M fixed (64) so the slope isolates the N dependence
            .filter(|m| m.extra("m").map(|v| v == 64.0 || v == 0.0).unwrap_or(true))
            .filter_map(|m| Some((m.extra("n")?, m.mean_ms())))
            .collect();
        if pts.len() < 2 {
            return None;
        }
        let (n0, t0) = pts[0];
        let (n1, t1) = pts[pts.len() - 1];
        Some((t1 / t0).ln() / (n1 / n0).ln())
    };
    if let (Some(sv), Some(sf)) = (slope("vanilla"), slope("flare")) {
        println!("\nlog-log slope: vanilla {sv:.2} (paper: ~2), FLARE {sf:.2} (paper: ~1)");
    }
    let path = save_results("fig2_scaling", &all)?;
    println!("results written to {path:?}");
    Ok(())
}
