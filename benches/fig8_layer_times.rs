//! Figure 8 reproduction: execution time of a *single* mixing layer —
//! vanilla self-attention vs Transolver physics attention vs FLARE — as a
//! function of sequence length.
//!
//! Paper claim: vanilla grows quadratically and becomes prohibitive past
//! ~1e4 points; physics attention scales linearly but with a larger
//! constant than FLARE at matched parameter counts.
//!
//! The baseline mixing layers only exist as AOT artifacts, so this bench
//! requires the XLA backend: build with `--features xla` against a real
//! xla_extension.  (The FLARE-only scaling story runs anywhere via
//! `cargo bench --bench fig2_scaling`.)
//!
//! Run: cargo bench --features xla --bench fig8_layer_times

#[cfg(feature = "xla")]
mod xla_only {
    use flare::bench::{quick_mode, save_results, Bench, Table};
    use flare::config::Manifest;
    use flare::model::init_params;
    use flare::runtime::literal::lit_f32;
    use flare::runtime::Runtime;
    use flare::util::rng::Rng;

    pub fn run() -> anyhow::Result<()> {
        let manifest = Manifest::load(Manifest::default_dir())?;
        anyhow::ensure!(!manifest.layers.is_empty(), "fig8 artifacts missing");
        let max_n = if quick_mode() { 4096 } else { usize::MAX };

        println!("=== Figure 8: single-layer execution time ===\n");
        let mut all = Vec::new();
        let mut table = Table::new(&["layer", "N", "params", "ms/fwd", "us/token"]);
        for ly in &manifest.layers {
            if ly.n > max_n {
                continue;
            }
            let rt = Runtime::cpu()?;
            let exe = rt.load(&ly.name, manifest.dir.join(&ly.file))?;
            let params = init_params(&ly.params, ly.param_count, manifest.seed);
            let p = lit_f32(&params, &[ly.param_count as i64])?;
            let mut rng = Rng::new(3);
            let x: Vec<f32> = (0..ly.n * ly.c).map(|_| rng.normal() as f32).collect();
            let xl = lit_f32(&x, &[ly.n as i64, ly.c as i64])?;
            let bench = if quick_mode() { Bench::quick() } else { Bench::default() };
            let mut meas = bench.run(&ly.name, || {
                let _ = rt.run_ref(&exe, &[&p, &xl]).unwrap();
            });
            meas.extras.push(("n".into(), ly.n as f64));
            table.row(vec![
                ly.mixer.clone(),
                ly.n.to_string(),
                ly.param_count.to_string(),
                format!("{:.2}", meas.mean_ms()),
                format!("{:.2}", meas.mean_ms() * 1e3 / ly.n as f64),
            ]);
            all.push(meas);
        }
        table.print();

        // per-token cost should stay ~flat for flare, grow for vanilla
        for mixer in ["flare", "vanilla", "transolver"] {
            let pts: Vec<(f64, f64)> = all
                .iter()
                .filter(|m| m.name.starts_with(&format!("ly_{mixer}")))
                .filter_map(|m| Some((m.extra("n")?, m.mean_ms())))
                .collect();
            if pts.len() >= 2 {
                let slope = (pts[pts.len() - 1].1 / pts[0].1).ln()
                    / (pts[pts.len() - 1].0 / pts[0].0).ln();
                println!("{mixer}: log-log time slope {slope:.2}");
            }
        }
        let path = save_results("fig8_layer_times", &all)?;
        println!("results written to {path:?}");
        Ok(())
    }
}

#[cfg(feature = "xla")]
fn main() -> anyhow::Result<()> {
    xla_only::run()
}

#[cfg(not(feature = "xla"))]
fn main() {
    eprintln!(
        "fig8_layer_times benchmarks the baseline AOT layer artifacts and \
         requires `--features xla`; see fig2_scaling for the native FLARE \
         scaling bench"
    );
}
