//! Figure 5 reproduction: million-token single-box scaling of the native
//! FLARE forward pass — time per token and peak memory vs N at fixed M.
//!
//! The paper's headline claim is 1M-point meshes on a single device; this
//! bench drives the fused single-pass mixer through a full model forward
//! (in-proj, FLARE block, out-proj) at N up to 10^6 and records the two
//! memory columns the CI gate enforces (`peak_rss_gb`, `bytes_per_token`)
//! alongside ns/token.  Because the mixer is O(N·M·D) with O(M·(D+TILE))
//! scratch, ns/token should stay ~flat in N and memory should scale
//! linearly with the activations — the run prints the ratio of ns/token
//! at the largest N to the N=64k point (target: within ~1.15x).
//!
//! A second sweep repeats the ladder on the bf16 storage tier
//! (`fig5_bf16_n*`) and hard-fails unless its bytes/token is <= 0.6x the
//! f32 column at the same N — the reduced-precision tier exists to cut
//! activation traffic, so failing to do so is a bench failure, not a note.
//!
//! No manifest artifacts needed: inputs are synthetic (the claim under
//! test is runtime scaling, not accuracy).  Peak RSS is measured per case
//! with a scoped probe (`RssScope`) so each N reports its own footprint
//! rather than the process-lifetime high-water mark, and the sweep runs
//! smallest-first as a belt-and-suspenders where the probe's kernel reset
//! is unavailable.
//!
//! Run: cargo bench --bench fig5_million    (FLARE_BENCH_QUICK=1 to smoke)

use flare::bench::{push_memory_extras, quick_mode, save_results, Bench, Measurement, Table};
use flare::config::ModelCfg;
use flare::model::forward::{forward_sample, ParamTable};
use flare::model::{build_spec, index_by_name, init_params};
use flare::util::rng::Rng;
use flare::util::stats::RssScope;
use flare::util::workspace::reset_high_water;

fn main() -> anyhow::Result<()> {
    let cfg = ModelCfg {
        mixer: "flare".into(),
        n: 0, // the native path takes N from the input length
        d_in: 3,
        d_out: 1,
        c: 64,
        heads: 4,
        m: 64,
        blocks: 1,
        kv_layers: 1,
        ffn_layers: 1,
        io_layers: 1,
        latent_sa_blocks: 0,
        shared_latents: false,
        scale: 0.25, // 1/sqrt(head_dim = 16)
        task: "regression".into(),
        vocab: 0,
        num_classes: 0,
    };
    let (entries, total) = build_spec(&cfg)?;
    let map = index_by_name(&entries);
    let params = init_params(&entries, total, 5);
    let p = ParamTable::new(&params, &map);

    // smallest-first: see the module docs on the RSS probe fallback
    let ns: &[usize] = if quick_mode() {
        &[4_096, 16_384, 65_536]
    } else {
        &[65_536, 262_144, 1_048_576]
    };
    let bench = if quick_mode() { Bench::quick() } else { Bench::default() };

    println!("=== Figure 5: million-token forward scaling at M = {} ===\n", cfg.m);
    let mut all: Vec<Measurement> = Vec::new();
    let mut table = Table::new(&["N", "ms/fwd", "ns/token", "peak RSS GB", "bytes/token"]);
    let mut rng = Rng::new(13);
    for &n in ns {
        eprintln!("running fig5_n{n}");
        let x: Vec<f32> = (0..n * cfg.d_in).map(|_| rng.normal() as f32).collect();
        // scope starts before warmup so first-touch page faults are counted
        let scope = RssScope::start();
        reset_high_water();
        let mut m = bench.run(&format!("fig5_n{n}"), || {
            let y = forward_sample(&cfg, &p, &x).expect("forward");
            std::hint::black_box(&y[0]);
        });
        let ns_per_token = m.per_iter.p50 * 1e6 / n as f64;
        m.extras.push(("n".into(), n as f64));
        m.extras.push(("ns_per_token".into(), ns_per_token));
        push_memory_extras(&mut m, &scope, n);
        table.row(vec![
            n.to_string(),
            format!("{:.1}", m.per_iter.p50),
            format!("{ns_per_token:.1}"),
            format!("{:.3}", m.extra("peak_rss_gb").unwrap_or(0.0)),
            format!("{:.0}", m.extra("bytes_per_token").unwrap_or(0.0)),
        ]);
        all.push(m);
    }
    table.print();

    // linearity check: ns/token at the largest N vs the smallest measured
    // reference point (64k in both quick and full sweeps)
    let npt = |n: f64| {
        all.iter()
            .find(|m| m.extra("n") == Some(n))
            .and_then(|m| m.extra("ns_per_token"))
    };
    if let (Some(base), Some(top)) = (npt(65_536.0), npt(*ns.last().unwrap() as f64)) {
        let ratio = top / base;
        let verdict = if ratio <= 1.15 {
            "within the 1.15x linear-extrapolation target"
        } else {
            "ABOVE the 1.15x target"
        };
        println!(
            "\nns/token at N={}: {top:.1} vs {base:.1} at N=65536 -> {ratio:.3}x ({verdict})",
            ns.last().unwrap(),
        );
    }

    // bf16 storage tier over the same sweep.  The point of the tier is the
    // activation footprint, so the bytes/token column must come in at
    // <= 0.6x the f32 column at the same N (the CI acceptance gate): a
    // bf16 run that fails to cut activation bytes aborts the bench loudly
    // here instead of uploading a silently-regressed BENCH_fig5.json.
    use flare::config::Precision;
    let pb = ParamTable::with_precision(&params, &map, Precision::Bf16, None);
    println!("\n=== Figure 5, bf16 storage tier (f32 accumulation) ===\n");
    let mut btable = Table::new(&["N", "ms/fwd", "ns/token", "bytes/token", "vs f32"]);
    for &n in ns {
        eprintln!("running fig5_bf16_n{n}");
        let x: Vec<f32> = (0..n * cfg.d_in).map(|_| rng.normal() as f32).collect();
        let scope = RssScope::start();
        reset_high_water();
        let mut m = bench.run(&format!("fig5_bf16_n{n}"), || {
            let y = forward_sample(&cfg, &pb, &x).expect("bf16 forward");
            std::hint::black_box(&y[0]);
        });
        let ns_per_token = m.per_iter.p50 * 1e6 / n as f64;
        m.extras.push(("n".into(), n as f64));
        m.extras.push(("ns_per_token".into(), ns_per_token));
        push_memory_extras(&mut m, &scope, n);
        let bpt = m.extra("bytes_per_token").unwrap_or(f64::MAX);
        let f32_bpt = all
            .iter()
            .find(|f| f.name == format!("fig5_n{n}"))
            .and_then(|f| f.extra("bytes_per_token"))
            .expect("f32 sweep runs first");
        let ratio = bpt / f32_bpt;
        anyhow::ensure!(
            ratio <= 0.6,
            "fig5_bf16_n{n}: bytes/token {bpt:.0} is {ratio:.2}x the f32 column \
             {f32_bpt:.0} — the bf16 tier must cut activation bytes (gate: <= 0.6x)"
        );
        btable.row(vec![
            n.to_string(),
            format!("{:.1}", m.per_iter.p50),
            format!("{ns_per_token:.1}"),
            format!("{bpt:.0}"),
            format!("{ratio:.2}x"),
        ]);
        all.push(m);
    }
    btable.print();

    let path = save_results("fig5_million", &all)?;
    println!("results written to {path:?}");
    Ok(())
}
