//! Figure 5 reproduction (CPU-scaled): large-N DrivAer-like training sweep
//! over (B, M) reporting test rel-L2, time per step and peak memory — the
//! paper's three panels for its 1M-point single-GPU study.
//!
//! CPU scaling: N = 16,384 points/geometry (paper: 1e6 on an H100 80GB).
//! Claims under test: error falls monotonically with B; time grows with B
//! and M; memory is dominated by N (nearly flat in M).
//!
//! Run: cargo bench --bench fig5_million

use flare::bench::{save_results, sweep_steps, train_measurement, Table};
use flare::config::Manifest;
use flare::runtime::default_backend;
use flare::util::stats::peak_rss_bytes;

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load(Manifest::default_dir())?;
    let steps = sweep_steps(40);
    let cases = manifest.cases_in_group("fig5");
    anyhow::ensure!(!cases.is_empty(), "fig5 artifacts missing");

    println!("=== Figure 5: large-N sweep over (B, M), steps = {steps} ===\n");
    let mut all = Vec::new();
    let mut table = Table::new(&["B", "M", "rel-L2", "s/step", "peak RSS GB"]);
    for case in &cases {
        let backend = default_backend()?;
        eprintln!("running {}", case.name);
        let mut m = train_measurement(backend.as_ref(), &manifest, case, steps)?;
        let rss = peak_rss_bytes().unwrap_or(0) as f64 / 1e9;
        m.extras.push(("blocks".into(), case.model.blocks as f64));
        m.extras.push(("latents".into(), case.model.m as f64));
        m.extras.push(("peak_rss_gb".into(), rss));
        table.row(vec![
            case.model.blocks.to_string(),
            case.model.m.to_string(),
            format!("{:.4}", m.extra("rel_l2").unwrap_or(f64::NAN)),
            format!("{:.2}", m.extra("ms_per_step").unwrap_or(0.0) / 1e3),
            format!("{rss:.2}"),
        ]);
        all.push(m);
    }
    table.print();

    // trend check: error at B=4 below error at B=1 for each M
    for m_latents in [32.0, 128.0] {
        let err_at = |b: f64| {
            all.iter()
                .find(|x| {
                    x.extra("blocks") == Some(b) && x.extra("latents") == Some(m_latents)
                })
                .and_then(|x| x.extra("rel_l2"))
        };
        if let (Some(e1), Some(e4)) = (err_at(1.0), err_at(4.0)) {
            println!(
                "M={m_latents}: rel-L2 B=1 {e1:.4} -> B=4 {e4:.4} ({})",
                if e4 < e1 { "improves, as in paper" } else { "no improvement at this budget" }
            );
        }
    }
    let path = save_results("fig5_million", &all)?;
    println!("results written to {path:?}");
    Ok(())
}
