//! Native train-step microbench: ms per fused AdamW optimizer step
//! (reverse-mode gradients + update) on the pure-Rust backend, swept over
//! model width and sequence length.
//!
//! This is the number the CI `bench-smoke` job tracks in
//! `BENCH_native.json` — the cost of one optimizer step is the unit of the
//! whole training loop, so regressions here are regressions everywhere.
//!
//! Run: cargo bench --bench train_step       (FLARE_BENCH_QUICK=1 to smoke)

use flare::bench::{quick_mode, save_results, Bench, Measurement, Table};
use flare::config::{CaseCfg, ModelCfg};
use flare::linalg::kernel::{
    gemm_bf16_acc, gemm_i8_scaled, matmul_f32, matmul_f32_reference, pack_bf16,
    quantize_rows_i8, scale_softmax_rows,
};
use flare::linalg::vexp::vexp;
use flare::model::{build_spec, init_params};
use flare::runtime::{make_backend, Backend, BatchInput, BatchTarget, NativeBackend, OptState};
use flare::train::AdamW;
use flare::util::comms::{CommsHub, GradExchange, Transport, WorkerExchange};
use flare::util::json::Json;
use flare::util::rng::Rng;

fn make_case(name: &str, n: usize, c: usize, m: usize, blocks: usize) -> CaseCfg {
    let model = ModelCfg {
        mixer: "flare".into(),
        n,
        d_in: 3,
        d_out: 1,
        c,
        heads: 4,
        m,
        blocks,
        kv_layers: 1,
        ffn_layers: 1,
        io_layers: 1,
        latent_sa_blocks: 0,
        shared_latents: false,
        scale: 1.0,
        task: "regression".into(),
        vocab: 0,
        num_classes: 0,
    };
    let (entries, total) = build_spec(&model).expect("spec");
    CaseCfg {
        name: name.into(),
        group: "bench".into(),
        dataset: "darcy".into(),
        dataset_meta: Json::Null,
        batch: 2,
        max_batch: 2,
        train_steps: 0,
        lr: 1e-3,
        model,
        param_count: total,
        artifacts: Default::default(),
        params: entries,
        precision: None,
    }
}

fn main() -> anyhow::Result<()> {
    // a synthetic manifest satisfies the Backend trait signature; the
    // native train step never touches artifacts
    let dir = std::env::temp_dir().join("flare_train_step_bench");
    std::fs::create_dir_all(&dir)?;
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"seed": 1, "cases": [], "mixers": [], "layers": []}"#,
    )?;
    let manifest = flare::config::Manifest::load(&dir)?;
    let backend = make_backend("native")?;

    let sweeps: &[(usize, usize, usize, usize)] = if quick_mode() {
        &[(256, 16, 16, 2), (1024, 32, 32, 2)]
    } else {
        &[(256, 16, 16, 2), (1024, 32, 32, 2), (4096, 32, 32, 2), (1024, 64, 64, 4)]
    };

    println!("=== native train step: ms per fused AdamW step ===\n");
    let bench = if quick_mode() { Bench::quick() } else { Bench::default() };
    let mut table = Table::new(&["N", "C", "M", "blocks", "params", "ms/step", "ns/token"]);
    let mut all: Vec<Measurement> = Vec::new();
    let mut rng = Rng::new(11);

    for &(n, c, m, blocks) in sweeps {
        let case = make_case(&format!("train_n{n}_c{c}"), n, c, m, blocks);
        let batch = case.batch;
        let x: Vec<f32> = (0..batch * n * 3).map(|_| rng.normal() as f32).collect();
        let y: Vec<f32> = (0..batch * n).map(|_| rng.normal() as f32).collect();
        let mut st = OptState::new(init_params(&case.params, case.param_count, 1));
        let mut step = 0usize;
        let mut meas = bench.run(&format!("train_step_n{n}_c{c}"), || {
            let loss = backend
                .train_step(
                    &manifest,
                    &case,
                    &mut st,
                    step,
                    1e-3,
                    BatchInput::Fields(&x),
                    BatchTarget::Fields(&y),
                )
                .expect("train step");
            assert!(loss.is_finite());
            step += 1;
        });
        meas.extras.push(("n".into(), n as f64));
        meas.extras.push(("c".into(), c as f64));
        meas.extras.push(("params".into(), case.param_count as f64));
        meas.extras
            .push(("threads".into(), NativeBackend::new().threads() as f64));
        table.row(vec![
            n.to_string(),
            c.to_string(),
            m.to_string(),
            blocks.to_string(),
            case.param_count.to_string(),
            format!("{:.2}", meas.mean_ms()),
            format!("{:.1}", meas.mean_ms() * 1e6 / (batch * n) as f64),
        ]);
        all.push(meas);
    }
    table.print();

    // kernel-level microbenches: the blocked/SIMD GEMM against the seed's
    // naive ikj loop (the before/after pair BENCH_native.json pins), plus
    // the fused softmax row kernel and the fused AdamW update
    println!("\n=== kernel microbenches: blocked vs naive ===\n");
    let mut ktable = Table::new(&["kernel", "shape", "ms", "GFLOP/s"]);
    let gemm_sizes: &[(usize, usize, usize)] = if quick_mode() {
        &[(512, 64, 64)]
    } else {
        &[(512, 64, 64), (1024, 256, 256)]
    };
    for &(m, k, n) in gemm_sizes {
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let flops = 2.0 * (m * k * n) as f64;
        let meas = bench.run(&format!("gemm_m{m}_k{k}_n{n}"), || {
            let c = matmul_f32(&a, &b, m, k, n);
            assert_eq!(c.len(), m * n);
        });
        ktable.row(vec![
            "gemm_blocked".into(),
            format!("{m}x{k}x{n}"),
            format!("{:.3}", meas.mean_ms()),
            format!("{:.2}", flops / (meas.mean_ms() * 1e6)),
        ]);
        all.push(meas);
        let meas = bench.run(&format!("gemm_naive_m{m}_k{k}_n{n}"), || {
            let c = matmul_f32_reference(&a, &b, m, k, n);
            assert_eq!(c.len(), m * n);
        });
        ktable.row(vec![
            "gemm_naive".into(),
            format!("{m}x{k}x{n}"),
            format!("{:.3}", meas.mean_ms()),
            format!("{:.2}", flops / (meas.mean_ms() * 1e6)),
        ]);
        all.push(meas);

        // reduced-precision tiers over the same shapes: bf16 storage with
        // f32 accumulation (pack once, stream u16 panels), and the int8
        // weight-quantized path (weights quantized once at "load", the
        // per-call cost is activation quant + the i8 dot + scale fold)
        let mut a16 = vec![0u16; m * k];
        let mut b16 = vec![0u16; k * n];
        pack_bf16(&a, &mut a16);
        pack_bf16(&b, &mut b16);
        let mut c16 = vec![0.0f32; m * n];
        let meas = bench.run(&format!("gemm_bf16_m{m}_k{k}_n{n}"), || {
            c16.fill(0.0);
            gemm_bf16_acc(&mut c16, &a16, &b16, m, k, n);
            assert!(c16[0].is_finite());
        });
        ktable.row(vec![
            "gemm_bf16".into(),
            format!("{m}x{k}x{n}"),
            format!("{:.3}", meas.mean_ms()),
            format!("{:.2}", flops / (meas.mean_ms() * 1e6)),
        ]);
        all.push(meas);

        // b laid out as the weight: [n rows, k cols], per-row absmax
        let mut wq = vec![0i8; n * k];
        let mut sw = vec![0.0f32; n];
        let bt: Vec<f32> = (0..n * k).map(|i| b[(i % k) * n + i / k]).collect();
        quantize_rows_i8(&bt, n, k, &mut wq, &mut sw);
        let mut xq = vec![0i8; m * k];
        let mut sx = vec![0.0f32; m];
        let mut c8 = vec![0.0f32; m * n];
        let meas = bench.run(&format!("gemm_int8_m{m}_k{k}_n{n}"), || {
            quantize_rows_i8(&a, m, k, &mut xq, &mut sx);
            c8.fill(0.0);
            gemm_i8_scaled(&mut c8, &xq, &sx, &wq, &sw, m, k, n);
            assert!(c8[0].is_finite());
        });
        ktable.row(vec![
            "gemm_int8".into(),
            format!("{m}x{k}x{n}"),
            format!("{:.3}", meas.mean_ms()),
            format!("{:.2}", flops / (meas.mean_ms() * 1e6)),
        ]);
        all.push(meas);
    }
    {
        let (rows, cols) = (4096usize, 64usize);
        let base: Vec<f32> = (0..rows * cols).map(|_| rng.normal() as f32).collect();
        let mut buf = base.clone();
        let meas = bench.run("softmax_rows_4096x64", || {
            buf.copy_from_slice(&base);
            scale_softmax_rows(&mut buf, rows, cols, 0.125);
        });
        ktable.row(vec![
            "softmax_rows".into(),
            format!("{rows}x{cols}"),
            format!("{:.3}", meas.mean_ms()),
            "-".into(),
        ]);
        all.push(meas);
    }
    {
        // the softmax/exp split: vectorized polynomial exp vs the scalar
        // libm loop it replaced — the per-element transcendental cost that
        // dominated the softmax rows before linalg::vexp landed
        let len = 1usize << 18;
        let base: Vec<f32> = (0..len).map(|_| rng.normal() as f32 * 8.0).collect();
        let mut buf = base.clone();
        let meas = bench.run("vexp_262144", || {
            buf.copy_from_slice(&base);
            vexp(&mut buf);
            assert!(buf[0].is_finite());
        });
        ktable.row(vec![
            "vexp".into(),
            format!("{len}"),
            format!("{:.3}", meas.mean_ms()),
            "-".into(),
        ]);
        all.push(meas);
        let meas = bench.run("exp_libm_262144", || {
            buf.copy_from_slice(&base);
            for v in buf.iter_mut() {
                *v = v.exp();
            }
            assert!(buf[0].is_finite());
        });
        ktable.row(vec![
            "exp_libm".into(),
            format!("{len}"),
            format!("{:.3}", meas.mean_ms()),
            "-".into(),
        ]);
        all.push(meas);
    }
    {
        let p = 1usize << 20;
        let grad: Vec<f32> = (0..p).map(|_| rng.normal() as f32 * 1e-3).collect();
        let mut st = OptState::new(vec![0.0f32; p]);
        let opt = AdamW::default();
        let mut step_i = 0usize;
        let meas = bench.run("adamw_fused_1m", || {
            opt.step(&mut st, &grad, step_i, 1e-3);
            step_i += 1;
        });
        ktable.row(vec![
            "adamw_fused".into(),
            format!("{p}"),
            format!("{:.3}", meas.mean_ms()),
            "-".into(),
        ]);
        all.push(meas);
    }
    ktable.print();

    // data-parallel gradient exchange: raw allreduce round-trip cost per
    // transport (worker root in, reduced total out — the per-micro-batch
    // collective `train --ranks K` pays), then a full train step at
    // ranks=1 vs ranks=2.  Worker ranks run on a thread with their own
    // backend; every rank is pinned to one compute thread so the ranks2/
    // ranks1 ratio isolates the data-parallel split itself (in the real
    // launcher each rank gets cores/K threads on top of this).
    println!("\n=== data-parallel exchange + ranks ===\n");
    let mut dtable = Table::new(&["op", "payload", "ms/round", "MB/s"]);
    let pc = if quick_mode() { 1usize << 18 } else { 1usize << 20 };
    for transport in [Transport::Shm, Transport::Tcp] {
        let sess = format!("bench-{}-{}", std::process::id(), transport.as_str());
        let hub = CommsHub::bind(transport, 2, pc, &sess)?;
        let addr = hub.addr();
        let wsess = sess.clone();
        let worker = std::thread::spawn(move || {
            let mut ex = match WorkerExchange::connect(&addr, &wsess, 1, 2, pc) {
                Ok(ex) => ex,
                Err(_) => return,
            };
            let grad = vec![1.0f32; pc];
            let mut total = vec![0.0f32; pc];
            // serve rounds until the coordinator drops the exchange
            loop {
                if ex.send_root(true, 1.0, &grad).is_err() {
                    break;
                }
                if ex.recv_total(&mut total).is_err() {
                    break;
                }
            }
        });
        let mut coord = hub.accept(|| Ok(()))?;
        let mut acc = vec![0.0f32; pc];
        let name = format!("allreduce_exchange_{}", transport.as_str());
        let mut meas = bench.run(&name, || {
            let roots = coord.gather().expect("gather");
            // fold the root in, like the reduction tree would
            for (a, &b) in acc.iter_mut().zip(roots[0].grad.iter()) {
                *a += b;
            }
            coord.broadcast(1.0, &acc).expect("broadcast");
        });
        // one round moves the payload twice: root in, total out
        let bytes_per_s = (pc * 4 * 2) as f64 / (meas.mean_ms() / 1e3);
        meas.extras.push(("payload_bytes".into(), (pc * 4) as f64));
        meas.extras.push(("bytes_per_s".into(), bytes_per_s));
        dtable.row(vec![
            name,
            format!("{} MB", pc * 4 >> 20),
            format!("{:.3}", meas.mean_ms()),
            format!("{:.1}", bytes_per_s / 1e6),
        ]);
        all.push(meas);
        drop(coord); // closes the doorbell; the worker loop exits
        worker.join().expect("exchange worker");
    }
    {
        let (n, c, m, blocks) = if quick_mode() { (256, 16, 16, 2) } else { (1024, 32, 32, 2) };
        let case = make_case("train_dp", n, c, m, blocks);
        let batch = case.batch;
        let x: Vec<f32> = (0..batch * n * 3).map(|_| rng.normal() as f32).collect();
        let y: Vec<f32> = (0..batch * n).map(|_| rng.normal() as f32).collect();
        // S=2 logical shards: rank 0 owns sample 0, rank 1 owns sample 1 —
        // the same layout single-process, so ranks1 is the exact arithmetic
        // ranks2 distributes
        let b1 = NativeBackend::with_threads(1).with_logical_shards(2);
        let mut st1 = OptState::new(init_params(&case.params, case.param_count, 1));
        let mut step1 = 0usize;
        let meas = bench.run("train_step_ranks1", || {
            b1.train_step(
                &manifest,
                &case,
                &mut st1,
                step1,
                1e-3,
                BatchInput::Fields(&x),
                BatchTarget::Fields(&y),
            )
            .expect("ranks1 step");
            step1 += 1;
        });
        dtable.row(vec![
            "train_step_ranks1".into(),
            format!("{} params", case.param_count),
            format!("{:.3}", meas.mean_ms()),
            "-".into(),
        ]);
        all.push(meas);

        let sess = format!("bench-{}-ranks2", std::process::id());
        let hub = CommsHub::bind(Transport::Shm, 2, case.param_count, &sess)?;
        let addr = hub.addr();
        let (wcase, wx, wy, wsess) = (case.clone(), x.clone(), y.clone(), sess.clone());
        let worker = std::thread::spawn(move || {
            let ex = match WorkerExchange::connect(&addr, &wsess, 1, 2, wcase.param_count) {
                Ok(ex) => ex,
                Err(_) => return,
            };
            let backend = NativeBackend::with_threads(1)
                .with_logical_shards(2)
                .with_dp(1, 2, Box::new(ex));
            let manifest = flare::config::Manifest::builtin("nowhere");
            let mut st = OptState::new(init_params(&wcase.params, wcase.param_count, 1));
            let mut step = 0usize;
            // lockstep with rank 0 until the coordinator drops the exchange
            while backend
                .train_step(
                    &manifest,
                    &wcase,
                    &mut st,
                    step,
                    1e-3,
                    BatchInput::Fields(&wx),
                    BatchTarget::Fields(&wy),
                )
                .is_ok()
            {
                step += 1;
            }
        });
        let ex = hub.accept(|| Ok(()))?;
        let b2 = NativeBackend::with_threads(1)
            .with_logical_shards(2)
            .with_dp(0, 2, Box::new(ex));
        let mut st2 = OptState::new(init_params(&case.params, case.param_count, 1));
        let mut step2 = 0usize;
        let meas = bench.run("train_step_ranks2", || {
            b2.train_step(
                &manifest,
                &case,
                &mut st2,
                step2,
                1e-3,
                BatchInput::Fields(&x),
                BatchTarget::Fields(&y),
            )
            .expect("ranks2 step");
            step2 += 1;
        });
        dtable.row(vec![
            "train_step_ranks2".into(),
            format!("{} params", case.param_count),
            format!("{:.3}", meas.mean_ms()),
            "-".into(),
        ]);
        all.push(meas);
        drop(b2); // closes the exchange; the worker's next round errors out
        worker.join().expect("ranks2 worker");
    }
    dtable.print();

    let path = save_results("train_step", &all)?;
    println!("results written to {path:?}");
    Ok(())
}
