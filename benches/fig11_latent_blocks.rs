//! Figure 11 reproduction: the Perceiver/LNO <-> FLARE continuum — vary
//! the number of latent self-attention blocks (L_B) against the number of
//! FLARE encode-decode blocks (B); each cell reports rel-L2, parameter
//! count and time per step.
//!
//! Paper claim: the optimum sits at the top-right corner — many
//! encode-decode blocks, ZERO latent-space blocks; adding latent SA only
//! costs parameters and time.
//!
//! Run: cargo bench --bench fig11_latent_blocks

use std::collections::BTreeMap;

use flare::bench::{save_results, sweep_steps, train_measurement, Table};
use flare::config::Manifest;
use flare::runtime::default_backend;

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load(Manifest::default_dir())?;
    let steps = sweep_steps(150);
    let cases = manifest.cases_in_group("fig11");
    anyhow::ensure!(!cases.is_empty(), "fig11 artifacts missing");

    println!("=== Figure 11: latent-SA blocks vs FLARE blocks, steps = {steps} ===\n");
    let mut all = Vec::new();
    let mut grid: BTreeMap<(usize, usize), (f64, usize, f64)> = BTreeMap::new();
    let total = cases.len();
    for (i, case) in cases.iter().enumerate() {
        let backend = default_backend()?;
        eprintln!("[{}/{total}] {}", i + 1, case.name);
        let m = train_measurement(backend.as_ref(), &manifest, case, steps)?;
        grid.insert(
            (case.model.blocks, case.model.latent_sa_blocks),
            (
                m.extra("rel_l2").unwrap_or(f64::NAN),
                case.param_count,
                m.extra("ms_per_step").unwrap_or(0.0),
            ),
        );
        all.push(m);
    }

    let bs: Vec<usize> = {
        let mut v: Vec<usize> = grid.keys().map(|(b, _)| *b).collect();
        v.sort_unstable();
        v.dedup();
        v
    };
    let lbs: Vec<usize> = {
        let mut v: Vec<usize> = grid.keys().map(|(_, l)| *l).collect();
        v.sort_unstable();
        v.dedup();
        v
    };
    let mut headers: Vec<String> = vec!["L_B \\ B".into()];
    headers.extend(bs.iter().map(|b| b.to_string()));
    let hdr: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(&hdr);
    for lb in &lbs {
        let mut row = vec![lb.to_string()];
        for b in &bs {
            row.push(
                grid.get(&(*b, *lb))
                    .map(|(e, p, ms)| format!("{e:.4}/{}k/{ms:.0}ms", p / 1000))
                    .unwrap_or_default(),
            );
        }
        table.row(row);
    }
    println!("cells: rel-L2 / params / ms-per-step");
    table.print();

    // paper's claim: best cell has L_B = 0 at the largest B
    let best = grid
        .iter()
        .min_by(|a, b| a.1 .0.partial_cmp(&b.1 .0).unwrap())
        .unwrap();
    println!(
        "\nbest cell: B={} L_B={} rel-L2 {:.4} (paper: optimum at L_B=0, max B)",
        best.0 .0, best.0 .1, best.1 .0
    );
    let path = save_results("fig11_latent_blocks", &all)?;
    println!("results written to {path:?}");
    Ok(())
}
