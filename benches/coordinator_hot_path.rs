//! Coordinator hot-path microbenchmarks (systems deliverable, not a paper
//! figure): batcher throughput, the native forward pass that serving rides
//! on, and end-to-end serving latency/throughput across flush deadlines
//! with the overhead of the coordinator relative to raw model execution.
//!
//! Parts 1-2 run on a clean machine; part 3 needs `artifacts/manifest.json`
//! for the served case's shapes (any backend).
//!
//! Run: cargo bench --bench coordinator_hot_path

use std::time::{Duration, Instant};

use flare::bench::{quick_mode, save_results, Bench, Table};
use flare::config::{CaseCfg, Manifest, ModelCfg};
use flare::coordinator::{Batcher, Server, ServerConfig};
use flare::model::{build_spec, init_params};
use flare::runtime::{default_backend, make_backend, Backend, BatchInput};
use flare::util::json::Json;

/// A Darcy-sized FLARE case declared entirely in Rust (no manifest).
fn synthetic_case() -> anyhow::Result<CaseCfg> {
    let model = ModelCfg {
        mixer: "flare".into(),
        n: 1024,
        d_in: 3,
        d_out: 1,
        c: 32,
        heads: 4,
        m: 32,
        blocks: 2,
        kv_layers: 3,
        ffn_layers: 3,
        io_layers: 2,
        latent_sa_blocks: 0,
        shared_latents: false,
        scale: 1.0,
        task: "regression".into(),
        vocab: 0,
        num_classes: 0,
    };
    let (entries, total) = build_spec(&model)?;
    Ok(CaseCfg {
        name: "synthetic_darcy".into(),
        group: "bench".into(),
        dataset: "darcy".into(),
        dataset_meta: Json::Null,
        batch: 2,
        max_batch: 2,
        train_steps: 0,
        lr: 1e-3,
        model,
        param_count: total,
        artifacts: Default::default(),
        params: entries,
        precision: None,
    })
}

fn main() -> anyhow::Result<()> {
    let mut all = Vec::new();
    let bench = if quick_mode() { Bench::quick() } else { Bench::default() };

    // 1. batcher logic throughput (pure data structure)
    let m1 = bench.run("batcher_push_pop_10k", || {
        let mut b: Batcher<u64> = Batcher::new(8, Duration::from_millis(1));
        for i in 0..10_000u64 {
            b.push(if i % 3 == 0 { "a" } else { "b" }, i);
            if i % 64 == 0 {
                while b.pop_ready(Instant::now()).is_some() {}
            }
        }
        let _ = b.drain_all();
    });
    println!(
        "batcher: {:.2} ms / 10k requests ({:.0} Mreq/s)",
        m1.mean_ms(),
        10.0 / m1.mean_ms()
    );
    all.push(m1);

    // 2. the native forward pass serving rides on (synthetic Darcy case)
    let case = synthetic_case()?;
    let backend = make_backend("native")?;
    let params = init_params(&case.params, case.param_count, 42);
    let x = vec![0.25f32; case.batch * case.model.n * case.model.d_in];
    let m2 = bench.run("native_forward_batch", || {
        let _ = backend
            .forward(&case, &params, BatchInput::Fields(&x), case.batch)
            .unwrap();
    });
    println!(
        "native forward (N={}, batch={}): {:.2} ms/batch ({:.2} ms/request)",
        case.model.n,
        case.batch,
        m2.mean_ms(),
        m2.mean_ms() / case.batch as f64
    );
    all.push(m2);

    // 2b. the zero-allocation serving entry: batched forward into a reused
    // reply buffer on the persistent worker pool
    let mut backend_mut = flare::runtime::NativeBackend::new();
    let mut out = Vec::new();
    let m2b = bench.run("native_forward_batch_into", || {
        backend_mut
            .forward_batch(&case, &params, BatchInput::Fields(&x), case.batch, &mut out)
            .unwrap();
    });
    println!(
        "native forward_batch (reused buffer): {:.2} ms/batch ({:.2} ms/request)",
        m2b.mean_ms(),
        m2b.mean_ms() / case.batch as f64
    );
    all.push(m2b);

    // 3. end-to-end serving vs raw execution (coordinator overhead)
    let manifest = Manifest::load(Manifest::default_dir());
    match manifest {
        Ok(manifest) if manifest.cases.iter().any(|c| c.name == "core_darcy_flare") => {
            let case = manifest.case("core_darcy_flare")?.clone();
            let x = vec![0.25f32; case.model.n * case.model.d_in];

            // raw: direct backend execution of a full batch
            let backend = default_backend()?;
            backend.prepare(&manifest, &case)?;
            let params = init_params(&case.params, case.param_count, manifest.seed);
            let mut xb = x.clone();
            xb.resize(case.batch * case.model.n * case.model.d_in, 0.25);
            let m3 = bench.run("raw_forward_batch", || {
                let _ = backend
                    .forward(&case, &params, BatchInput::Fields(&xb), case.batch)
                    .unwrap();
            });
            let raw_per_req = m3.mean_ms() / case.batch as f64;
            println!(
                "raw execute: {:.2} ms/batch ({raw_per_req:.2} ms/request)",
                m3.mean_ms()
            );
            all.push(m3);
            drop(backend);

            // served: through router + batcher + channels, saturating clients
            let mut table =
                Table::new(&["max_wait ms", "req/s", "p50 ms", "p95 ms", "overhead %"]);
            for wait_ms in [1u64, 5, 20] {
                let server = Server::start(
                    manifest.dir.clone(),
                    ServerConfig {
                        cases: vec![case.name.clone()],
                        max_wait: Duration::from_millis(wait_ms),
                        params: vec![],
                        backend: None,
                        ..ServerConfig::default()
                    },
                )?;
                let requests: usize = if quick_mode() { 16 } else { 64 };
                let clients = 4;
                let t = Instant::now();
                std::thread::scope(|scope| {
                    for _ in 0..clients {
                        let server = &server;
                        let x = &x;
                        let n = case.model.n;
                        scope.spawn(move || {
                            for _ in 0..requests / clients {
                                let _ = server.infer(x.clone(), n).unwrap();
                            }
                        });
                    }
                });
                let wall = t.elapsed().as_secs_f64();
                let lat = server.metrics.summary("latency_ms").unwrap();
                let served = (requests / clients) * clients;
                let per_req_served = wall * 1e3 / served as f64;
                table.row(vec![
                    wait_ms.to_string(),
                    format!("{:.1}", served as f64 / wall),
                    format!("{:.2}", lat.p50),
                    format!("{:.2}", lat.p95),
                    format!("{:.0}", (per_req_served / raw_per_req - 1.0) * 100.0),
                ]);
                server.shutdown()?;
            }
            println!("\nserving engine vs flush deadline:");
            table.print();
        }
        Ok(_) => println!("\n(skipping serving section: manifest has no core_darcy_flare case)"),
        Err(e) => println!("\n(skipping serving section: {e})"),
    }

    let path = save_results("coordinator_hot_path", &all)?;
    println!("\nresults written to {path:?}");
    Ok(())
}
