//! Coordinator hot-path microbenchmarks (systems deliverable, not a paper
//! figure): batcher throughput, literal marshalling cost, end-to-end
//! serving latency/throughput across flush deadlines, and the overhead of
//! the coordinator relative to raw model execution.
//!
//! Run: cargo bench --bench coordinator_hot_path

use std::time::{Duration, Instant};

use flare::bench::{quick_mode, save_results, Bench, Table};
use flare::config::Manifest;
use flare::coordinator::{Batcher, Server, ServerConfig};
use flare::model::init_params;
use flare::runtime::literal::{lit_f32, to_vec_f32};
use flare::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let mut all = Vec::new();
    let bench = if quick_mode() { Bench::quick() } else { Bench::default() };

    // 1. batcher logic throughput (pure data structure)
    let m1 = bench.run("batcher_push_pop_10k", || {
        let mut b: Batcher<u64> = Batcher::new(8, Duration::from_millis(1));
        for i in 0..10_000u64 {
            b.push(if i % 3 == 0 { "a" } else { "b" }, i);
            if i % 64 == 0 {
                while b.pop_ready(Instant::now()).is_some() {}
            }
        }
        let _ = b.drain_all();
    });
    println!(
        "batcher: {:.2} ms / 10k requests ({:.0} Mreq/s)",
        m1.mean_ms(),
        10.0 / m1.mean_ms()
    );
    all.push(m1);

    // 2. literal marshalling (the host <-> device copy on the hot path)
    let data = vec![0.5f32; 1024 * 3 * 2];
    let m2 = bench.run("literal_marshal_roundtrip", || {
        let l = lit_f32(&data, &[2, 1024, 3]).unwrap();
        let _ = to_vec_f32(&l).unwrap();
    });
    println!(
        "literal round-trip (2x1024x3 f32): {:.3} ms ({:.1} GB/s)",
        m2.mean_ms(),
        2.0 * data.len() as f64 * 4.0 / (m2.mean_ms() / 1e3) / 1e9
    );
    all.push(m2);

    // 3. end-to-end serving vs raw execution (coordinator overhead)
    let manifest = Manifest::load(Manifest::default_dir())?;
    if manifest.cases.iter().any(|c| c.name == "core_darcy_flare") {
        let case = manifest.case("core_darcy_flare")?.clone();
        let x = vec![0.25f32; case.model.n * case.model.d_in];

        // raw: direct PJRT execution of a full batch
        let rt = Runtime::cpu()?;
        let exe = rt.load("fwd", manifest.artifact_path(&case, "fwd")?)?;
        let params = init_params(&case.params, case.param_count, manifest.seed);
        let p = lit_f32(&params, &[case.param_count as i64])?;
        let mut xb = x.clone();
        xb.resize(case.batch * case.model.n * case.model.d_in, 0.25);
        let xl = lit_f32(
            &xb,
            &[case.batch as i64, case.model.n as i64, case.model.d_in as i64],
        )?;
        let m3 = bench.run("raw_forward_batch", || {
            let _ = rt.run_ref(&exe, &[&p, &xl]).unwrap();
        });
        let raw_per_req = m3.mean_ms() / case.batch as f64;
        println!(
            "raw execute: {:.2} ms/batch ({raw_per_req:.2} ms/request)",
            m3.mean_ms()
        );
        all.push(m3);
        drop(rt);

        // served: through router + batcher + channels, saturating clients
        let mut table = Table::new(&["max_wait ms", "req/s", "p50 ms", "p95 ms", "overhead %"]);
        for wait_ms in [1u64, 5, 20] {
            let server = Server::start(
                manifest.dir.clone(),
                ServerConfig {
                    cases: vec![case.name.clone()],
                    max_wait: Duration::from_millis(wait_ms),
                    params: vec![],
                },
            )?;
            let requests: usize = if quick_mode() { 16 } else { 64 };
            let clients = 4;
            let t = Instant::now();
            std::thread::scope(|scope| {
                for _ in 0..clients {
                    let server = &server;
                    let x = &x;
                    let n = case.model.n;
                    scope.spawn(move || {
                        for _ in 0..requests / clients {
                            let _ = server.infer(x.clone(), n).unwrap();
                        }
                    });
                }
            });
            let wall = t.elapsed().as_secs_f64();
            let lat = server.metrics.summary("latency_ms").unwrap();
            let served = (requests / clients) * clients;
            let per_req_served = wall * 1e3 / served as f64;
            table.row(vec![
                wait_ms.to_string(),
                format!("{:.1}", served as f64 / wall),
                format!("{:.2}", lat.p50),
                format!("{:.2}", lat.p95),
                format!("{:.0}", (per_req_served / raw_per_req - 1.0) * 100.0),
            ]);
            server.shutdown()?;
        }
        println!("\nserving engine vs flush deadline:");
        table.print();
    }

    let path = save_results("coordinator_hot_path", &all)?;
    println!("\nresults written to {path:?}");
    Ok(())
}
